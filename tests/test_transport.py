"""Unified Transport protocol: fairness, STATE semantics, backoff, codecs.

Covers the satellite requirements of the transport refactor:
  * MpscQueue round-robin fairness — a producer that keeps its ring full
    cannot starve the others,
  * the STATE channel recv path (collision -> retry -> freshest value)
    exercised through the shared Transport protocol,
  * the Table-1 Backoff discipline (spin on transient, yield/sleep on
    stable) and the generic drain/blocking helpers,
  * packet-mode burst operations (send_burst/drain_burst): FIFO across
    wrap-around, partial drain, full-ring refusal, and SPSC
    producer/consumer races (hypothesis-guarded).
"""
import threading

import pytest

try:  # optional dev dependency; property tests skip without it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core import nbb, nbw, states, transport
from repro.core.channels import Channel, ChannelType, Domain
from repro.core.host_queue import LockedQueue, MpscQueue, SpscQueue
from repro.core.transport import (Backoff, CodecTransport, OpHandle,
                                  StateTransport, Transport, drain,
                                  recv_blocking, recv_i, send_blocking,
                                  send_i)


# ---------------------------------------------------------------------------
# Protocol conformance: every queue family is a Transport.
# ---------------------------------------------------------------------------
def test_structural_conformance():
    dom = Domain()
    state_ch = dom.connect(ChannelType.STATE, dom.create_endpoint(0, 1),
                           dom.create_endpoint(1, 1))
    scalar_ch = dom.connect(ChannelType.SCALAR, dom.create_endpoint(0, 2),
                            dom.create_endpoint(1, 2))
    for t in (SpscQueue(4), LockedQueue(4),
              StateTransport(nbw.HostNBW()), state_ch.transport,
              scalar_ch.transport):
        assert isinstance(t, Transport), type(t)
    # MpscQueue is receive-side only: producers go through their private
    # SPSC rings (each a full Transport) to keep the single-writer
    # invariant; the consumer surface is try_recv/drain.
    mpsc = MpscQueue(2)
    assert isinstance(mpsc.producer(0), Transport)
    assert callable(mpsc.try_recv) and callable(mpsc.drain)
    assert not hasattr(mpsc, "send")


def test_channel_has_no_ctype_dispatch_in_hot_path():
    """send/recv are pure delegation: the same code object regardless of
    channel type (dispatch happens once, at connect)."""
    import inspect
    src = inspect.getsource(Channel.send) + inspect.getsource(Channel.recv)
    assert "ctype" not in src and "isinstance" not in src


def test_spsc_drain():
    q = SpscQueue(8)
    for i in range(5):
        assert q.send(i) == nbb.OK
    assert q.drain() == [0, 1, 2, 3, 4]
    assert q.drain() == []
    q.send(9)
    assert q.drain(max_items=0) == []
    assert q.drain() == [9]


# ---------------------------------------------------------------------------
# MpscQueue round-robin fairness: no producer starvation.
# ---------------------------------------------------------------------------
class TestMpscFairness:
    def test_full_ring_cannot_starve_others(self):
        """Producer 0 keeps its ring full; producers 1..3 must still get
        their items through within bounded delay (round-robin drain)."""
        n = 4
        q = MpscQueue(n, capacity_per_producer=4)
        # Ring 0 stays saturated throughout.
        for _ in range(4):
            assert q.producer(0).send(("hog", 0)) == nbb.OK
        for pid in range(1, n):
            assert q.producer(pid).send(("meek", pid)) == nbb.OK

        got = []
        for _ in range(n):
            status, item = q.try_recv()
            assert status == nbb.OK
            got.append(item)
            # The hog instantly refills any slot it gave up.
            while q.producer(0).send(("hog", 0)) == nbb.OK:
                pass
        # Within n consecutive reads every producer was served once:
        # round-robin never returns to ring 0 before visiting 1..3.
        producers_seen = {pid for (_, pid) in got}
        assert producers_seen == set(range(n)), got

    def test_round_robin_cursor_rotates(self):
        q = MpscQueue(3, capacity_per_producer=8)
        for pid in range(3):
            for i in range(3):
                q.producer(pid).send((pid, i))
        order = [q.try_recv()[1][0] for _ in range(9)]
        # Perfect rotation when all rings are non-empty.
        assert order == [0, 1, 2] * 3, order

    def test_threaded_hog_vs_meek_producer(self):
        """A flat-out producer and a trickle producer: the trickle's items
        all arrive (exactly once, in order) despite the hog's pressure."""
        q = MpscQueue(2, capacity_per_producer=8)
        stop = threading.Event()
        n_meek = 200

        def hog():
            i = 0
            while not stop.is_set():
                q.producer(0).send(("hog", i))
                i += 1

        def meek():
            for i in range(n_meek):
                send_blocking(q.producer(1), ("meek", i),
                              should_stop=stop.is_set)

        got_meek = []

        def consumer():
            while len(got_meek) < n_meek:
                status, item = q.try_recv()
                if status == nbb.OK and item[0] == "meek":
                    got_meek.append(item[1])

        threads = [threading.Thread(target=f) for f in (hog, meek, consumer)]
        for t in threads:
            t.start()
        threads[1].join(timeout=60)
        threads[2].join(timeout=60)
        stop.set()
        threads[0].join(timeout=10)
        assert got_meek == list(range(n_meek)), "meek producer starved"


# ---------------------------------------------------------------------------
# STATE channel recv path through the Transport protocol.
# ---------------------------------------------------------------------------
class TestStateTransport:
    def test_collision_then_retry_then_freshest(self):
        """Deterministic collision: a write-in-progress (odd version) maps
        to the transient Table-1 status; once the writer commits, recv
        returns the freshest committed value."""
        cell = nbw.HostNBW(depth=2)
        t = StateTransport(cell)
        assert t.try_recv() == (nbb.BUFFER_EMPTY, None)   # nothing published

        t.send("v1")
        t.send("v2")
        # Simulate a writer mid-publish exactly as HostNBW.write does:
        # bump the version to odd, write the buffer, don't commit yet.
        v = cell._version
        cell._version = v + 1
        status, payload = t.try_recv()
        assert status == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING  # -> spin
        assert payload is None
        # Writer commits; the retry observes the freshest value.
        cell._bufs[((v // 2) + 1) % cell._depth] = "v3"
        cell._version = v + 2
        assert t.try_recv() == (nbb.OK, "v3")
        # State semantics: re-reading the same value is legal.
        assert t.try_recv() == (nbb.OK, "v3")

    def test_recv_blocking_rides_out_collisions(self):
        cell = nbw.HostNBW(depth=2)
        t = StateTransport(cell)
        t.send(1)
        v = cell._version
        cell._version = v + 1                  # stuck mid-write...

        def commit():
            cell._bufs[((v // 2) + 1) % cell._depth] = 42
            cell._version = v + 2              # ...commits shortly after

        timer = threading.Timer(0.02, commit)
        timer.start()
        status, payload = recv_blocking(t, timeout_s=5)
        timer.join()
        assert (status, payload) == (nbb.OK, 42)

    def test_state_channel_through_domain(self):
        """End-to-end: STATE channel writer storm, reader sees monotone
        freshest values via the Transport recv path."""
        dom = Domain()
        ch = dom.connect(ChannelType.STATE, dom.create_endpoint(0, 5),
                         dom.create_endpoint(1, 5), nbw_depth=8)
        n = 5_000
        errors = []

        def writer():
            for i in range(1, n + 1):
                assert ch.send(i) == nbb.OK    # never blocks, never FULL

        def reader():
            last = 0
            while last < n:
                status, v = ch.recv()
                if status == nbb.OK:
                    if v < last:
                        errors.append((last, v))
                        return
                    last = v
        tw, tr = threading.Thread(target=writer), threading.Thread(target=reader)
        tr.start(); tw.start()
        tw.join(timeout=30); tr.join(timeout=30)
        assert not errors, errors[0]

    def test_state_drain_is_at_most_one_item(self):
        t = StateTransport(nbw.HostNBW(depth=2))
        assert t.drain() == []
        for i in range(5):
            t.send(i)
        assert t.drain() == [4]               # freshest only, not FIFO


# ---------------------------------------------------------------------------
# Backoff discipline + codec composition.
# ---------------------------------------------------------------------------
class TestBackoffAndCodec:
    def test_transient_spins_before_yield(self):
        import time as _time
        b = Backoff(spins=8, yields=4, sleep_init=1e-5, sleep_max=1e-4)
        t0 = _time.perf_counter()
        for _ in range(8):
            b.wait(nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING)
        spin_t = _time.perf_counter() - t0
        assert spin_t < 0.05                  # pure spins: near-instant

    def test_sleep_is_bounded(self):
        b = Backoff(spins=0, yields=0, sleep_init=1e-5, sleep_max=5e-4)
        import time as _time
        t0 = _time.perf_counter()
        for _ in range(30):                   # would be 10s+ if unbounded
            b.wait(nbb.BUFFER_EMPTY)
        assert _time.perf_counter() - t0 < 1.0

    def test_send_blocking_timeout_on_full_ring(self):
        q = SpscQueue(1)
        assert q.send("x") == nbb.OK
        assert send_blocking(q, "y", timeout_s=0.05) is False
        assert q.drain() == ["x"]             # rejected payload not enqueued

    def test_recv_blocking_timeout_on_empty(self):
        status, payload = recv_blocking(SpscQueue(1), timeout_s=0.05)
        assert status == nbb.BUFFER_EMPTY and payload is None

    def test_codec_roundtrip_and_status_passthrough(self):
        t = CodecTransport(SpscQueue(2), encode=lambda x: x * 2,
                           decode=lambda x: x // 2)
        assert t.send(21) == nbb.OK
        assert t.send(5) == nbb.OK
        assert t.send(1) == nbb.BUFFER_FULL   # status passes through
        assert t.try_recv() == (nbb.OK, 21)
        assert t.drain() == [5]

    def test_generic_drain_helper(self):
        q = LockedQueue(8)
        for i in range(6):
            q.send(i)
        assert drain(q, max_items=4) == [0, 1, 2, 3]
        assert drain(q) == [4, 5]


# ---------------------------------------------------------------------------
# Packet-mode bursts (paper Tables 5-7): one counter pair per block.
# ---------------------------------------------------------------------------
class TestBurstOps:
    def test_fifo_across_wraparound(self):
        """Alternating bursts through an 8-slot ring force every span
        shape (head-only, wrapped two-slice); FIFO must hold across all
        of them."""
        q = SpscQueue(8)
        sent, got = [], []
        i = 0
        for size in (5, 6, 7, 3, 8, 1, 6, 4):
            vals = list(range(i, i + size))
            status, n = q.send_burst(vals)
            assert n == size and status == nbb.OK
            sent += vals
            i += size
            got += q.drain_burst()
        assert got == sent
        assert q.drain_burst() == [] and len(q) == 0

    def test_partial_drain_leaves_remainder_in_order(self):
        q = SpscQueue(8)
        assert q.send_burst(list(range(6))) == (nbb.OK, 6)
        assert q.drain_burst(2) == [0, 1]
        assert q.drain_burst(3) == [2, 3, 4]
        # remainder still FIFO-composable with scalar ops
        assert q.try_recv() == (nbb.OK, 5)
        assert q.drain_burst(4) == []

    def test_full_ring_send_burst_refusal(self):
        q = SpscQueue(2)
        assert q.send_burst(["a", "b"]) == (nbb.OK, 2)
        status, n = q.send_burst(["c"])
        assert status == nbb.BUFFER_FULL and n == 0
        assert q.drain_burst() == ["a", "b"]    # nothing leaked in

    def test_partial_send_accepts_longest_prefix(self):
        q = SpscQueue(4)
        q.send("x")
        status, n = q.send_burst(list(range(5)))
        assert status == nbb.BUFFER_FULL and n == 3
        assert q.drain_burst() == ["x", 0, 1, 2]

    def test_burst_interops_with_scalar_ops(self):
        """Bursts and scalar insert/read share the same counters, so they
        interleave freely on one ring."""
        q = SpscQueue(8)
        q.send(0)
        assert q.send_burst([1, 2, 3]) == (nbb.OK, 3)
        assert q.try_recv() == (nbb.OK, 0)
        q.send(4)
        assert q.drain_burst() == [1, 2, 3, 4]

    def test_mpsc_drain_burst_preserves_per_producer_fifo(self):
        q = MpscQueue(3, capacity_per_producer=8)
        for pid in range(3):
            assert q.producer(pid).send_burst(
                [(pid, i) for i in range(4)]) == (nbb.OK, 4)
        got = q.drain_burst()
        assert len(got) == 12
        for pid in range(3):
            assert [i for (p, i) in got if p == pid] == list(range(4))

    def test_locked_queue_burst_parity(self):
        """The mutex baseline speaks the same burst surface (A/B swaps
        stay caller-transparent)."""
        q = LockedQueue(4)
        assert q.send_burst([1, 2, 3, 4, 5]) == (nbb.BUFFER_FULL, 4)
        assert q.drain_burst(2) == [1, 2]
        assert q.send_burst([5]) == (nbb.OK, 1)
        assert q.drain_burst() == [3, 4, 5]

    def test_codec_burst_encodes_whole_block(self):
        t = CodecTransport(SpscQueue(8), encode=lambda x: x * 2,
                           decode=lambda x: x // 2)
        assert t.send_burst([1, 2, 3]) == (nbb.OK, 3)
        assert t.inner.drain_burst(1) == [2]    # encoded on the wire
        assert t.drain_burst() == [2, 3]

    def test_state_burst_keeps_freshest_only(self):
        t = StateTransport(nbw.HostNBW(depth=4))
        assert t.send_burst([1, 2, 3]) == (nbb.OK, 3)   # writes never block
        assert t.drain_burst() == [3]           # state semantics, not FIFO

    def _race(self, burst_sizes, capacity=8):
        """One producer sending bursts of the given sizes races one
        consumer draining bursts: every item arrives exactly once, in
        FIFO order, through spans that wrap the ring arbitrarily."""
        q = SpscQueue(capacity)
        total = sum(burst_sizes)
        got = []

        def producer():
            i = 0
            for size in burst_sizes:
                vals = list(range(i, i + size))
                while vals:
                    _, n = q.send_burst(vals)
                    vals = vals[n:]
                i += size

        def consumer():
            while len(got) < total:
                got.extend(q.drain_burst())

        # daemon: a lost-item bug must fail the assert, not hang the
        # interpreter at exit behind a spinning consumer thread
        ts = [threading.Thread(target=producer, daemon=True),
              threading.Thread(target=consumer, daemon=True)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in ts), "burst race livelocked"
        assert got == list(range(total)), "burst FIFO violated under race"

    def test_spsc_burst_race_deterministic(self):
        self._race([3, 8, 1, 5, 12, 2, 7, 9, 4, 6] * 20)

    if st is not None:

        @settings(max_examples=25, deadline=None)
        @given(sizes=st.lists(st.integers(min_value=1, max_value=12),
                              min_size=1, max_size=40),
               capacity=st.integers(min_value=1, max_value=9))
        def test_spsc_burst_race_property(self, sizes, capacity):
            """Hypothesis chooses the burst shapes and ring capacity; the
            exactly-once FIFO property must hold for all of them."""
            self._race(sizes, capacity=capacity)


# ---------------------------------------------------------------------------
# Non-blocking operation handles (MCAPI *_i / test / wait / cancel).
# ---------------------------------------------------------------------------
class TestOpHandle:
    def test_uncontended_send_completes_eagerly(self):
        q = SpscQueue(4)
        h = q.send_i("x")
        assert h.completed and h.done and not h.cancelled
        assert q.drain() == ["x"]

    def test_send_pending_on_full_then_polls_through(self):
        q = SpscQueue(1)
        assert q.send_i("a").completed
        h = q.send_i("b")
        assert not h.done and h.last_status == nbb.BUFFER_FULL
        assert h.test() is False            # still full
        assert q.try_recv() == (nbb.OK, "a")
        assert h.test() is True             # slot freed -> completes
        assert q.drain() == ["b"]

    def test_recv_pending_on_empty_then_wait(self):
        q = SpscQueue(2)
        h = q.recv_i()
        assert not h.done and h.last_status == nbb.BUFFER_EMPTY
        timer = threading.Timer(0.02, lambda: q.send(41))
        timer.start()
        assert h.wait(timeout_s=5) is True
        timer.join()
        assert h.result == 41

    def test_wait_timeout_leaves_handle_pending(self):
        """MCAPI wait with timeout: the op is NOT aborted — it can still
        be polled to completion or cancelled afterwards."""
        q = SpscQueue(2)
        h = q.recv_i()
        assert h.wait(timeout_s=0.02) is False
        assert h.state == states.OP_PENDING
        q.send("late")
        assert h.wait(timeout_s=1) is True and h.result == "late"

    def test_cancel_pending_recv(self):
        q = SpscQueue(2)
        h = q.recv_i()
        assert h.cancel() is True
        assert h.cancelled and h.cancel() is False
        q.send("x")
        assert h.test() is False            # cancelled handles never run
        assert h.wait(timeout_s=0.05) is False
        assert q.drain() == ["x"]           # the item was NOT consumed

    def test_cancel_after_completion_loses(self):
        q = SpscQueue(2)
        q.send(1)
        h = q.recv_i()                      # eager attempt completes
        assert h.completed
        assert h.cancel() is False          # exactly one terminal state
        assert h.completed and h.result == 1

    def test_exactly_one_terminal_state_under_race(self):
        """N cancellers race one poller over many rounds: every handle
        ends in exactly one terminal state, and an item consumed by a
        cancelled handle is parked in late_result, never lost."""
        for _ in range(200):
            q = SpscQueue(2)
            q.send("item")
            # raw OpHandle (no eager attempt), so the race is live
            h = OpHandle(q.try_recv, "race")
            results = []

            def poller():
                results.append(("poll", h.test()))

            def canceller():
                results.append(("cancel", h.cancel()))

            ts = [threading.Thread(target=f)
                  for f in (poller, canceller, canceller)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(10)
            assert h.state in (states.OP_COMPLETED, states.OP_CANCELLED)
            cancel_wins = sum(1 for k, r in results if k == "cancel" and r)
            if h.completed:
                assert cancel_wins == 0 and h.result == "item"
            else:
                assert cancel_wins == 1
                # if the poll's pop landed anyway, the item is parked
                if ("poll", False) in results and h.attempted_ok:
                    assert h.late_result == "item"

    def test_overlap_work_with_inflight_exchange(self):
        """The point of *_i: the caller issues the op, does other work,
        then collects — no retry loop at the call site."""
        q = SpscQueue(1)
        consumer_got = []

        def consumer():
            h = recv_i(q)
            while not h.test():
                pass                         # overlapped "work"
            consumer_got.append(h.result)

        t = threading.Thread(target=consumer)
        t.start()
        hs = send_i(q, "payload")
        assert hs.wait(timeout_s=5)
        t.join(10)
        assert consumer_got == ["payload"]

    def test_blocking_calls_are_handle_wrappers(self):
        """send_blocking/recv_blocking are layered over handle + wait."""
        import inspect
        src = (inspect.getsource(transport.send_blocking)
               + inspect.getsource(transport.recv_blocking))
        assert "send_i" in src and "recv_i" in src and ".wait(" in src

    def test_handles_on_every_transport(self):
        dom = Domain()
        scalar = dom.connect(ChannelType.SCALAR, dom.create_endpoint(0, 11),
                             dom.create_endpoint(1, 11))
        for t in (SpscQueue(4), LockedQueue(4), scalar.transport):
            assert t.send_i(3).completed
            h = t.recv_i()
            assert h.completed and h.result == 3
        mp = MpscQueue(2)
        mp.producer(1).send("m")
        assert mp.recv_i().result == "m"

    def test_channel_typed_variants_enforce_format(self):
        dom = Domain()
        msg = dom.connect(ChannelType.MESSAGE, dom.create_endpoint(0, 12),
                          dom.create_endpoint(1, 12))
        pkt = dom.connect(ChannelType.PACKET, dom.create_endpoint(0, 13),
                          dom.create_endpoint(1, 13))
        sca = dom.connect(ChannelType.SCALAR, dom.create_endpoint(0, 14),
                          dom.create_endpoint(1, 14))
        assert msg.msg_send_i({"k": 1}).completed
        assert msg.msg_recv_i().result == {"k": 1}
        assert pkt.pkt_send_i(b"bytes").completed
        assert pkt.pkt_recv_i().result == b"bytes"
        assert sca.scalar_send_i(-7).completed
        assert sca.scalar_recv_i().result == -7
        with pytest.raises(ValueError):
            msg.pkt_send_i(b"wrong format")
        with pytest.raises(ValueError):
            sca.msg_recv_i()
        with pytest.raises(ValueError):
            pkt.scalar_send_i(1)

    def test_message_priority_fifo(self):
        """MESSAGE delivery is priority FIFO, as the format documents
        (satellite of DESIGN.md §12): lower class number drains first,
        FIFO within a class, unprioritized sends land least urgent."""
        for lock_free in (True, False):
            dom = Domain(lock_free=lock_free)
            msg = dom.connect(ChannelType.MESSAGE,
                              dom.create_endpoint(0, 20),
                              dom.create_endpoint(1, 20))
            assert msg.msg_send("n1", priority=1) == nbb.OK
            assert msg.send("plain") == nbb.OK          # least urgent
            assert msg.msg_send("h1", priority=0) == nbb.OK
            assert msg.msg_send_i("h2", priority=0).completed
            assert msg.msg_send("n2", priority=1) == nbb.OK
            got = [msg.recv()[1] for _ in range(5)]
            assert got == ["h1", "h2", "n1", "n2", "plain"]
            assert msg.recv() == (nbb.BUFFER_EMPTY, None)

    def test_message_priority_clamped_and_bursts(self):
        dom = Domain(msg_priorities=2)
        msg = dom.connect(ChannelType.MESSAGE, dom.create_endpoint(0, 21),
                          dom.create_endpoint(1, 21))
        assert msg.msg_send("deep", priority=99) == nbb.OK   # clamps to 1
        assert msg.msg_send("top", priority=0) == nbb.OK
        # drain_burst serves whole classes in priority order
        assert msg.drain_burst() == ["top", "deep"]
        with pytest.raises(ValueError):
            Domain(msg_priorities=0)

    def test_priority_transport_transient_status(self):
        """A mid-insert producer in ANY class surfaces the transient
        empty status so the consumer spins instead of sleeping; a
        committed item in a less urgent class still drains through it."""
        from repro.core.transport import PriorityTransport
        rings = [SpscQueue(4), SpscQueue(4)]
        tp = PriorityTransport(rings)
        assert tp.try_recv() == (nbb.BUFFER_EMPTY, None)
        rings[1].insert_item("low")
        rings[0]._uc += 1               # class-0 announced, not committed
        status, item = tp.try_recv()
        assert status == nbb.OK and item == "low"   # committed wins now
        assert tp.try_recv()[0] == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING

