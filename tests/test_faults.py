"""Fault-injection layer units (DESIGN.md §13): plans, transport faults,
torn-span safety, and pool probe/quarantine semantics.

Covers the tentpole's core machinery below the engine:
  * FaultPlan scheduling — nth/times windows, fnmatch site classes,
    pause() re-entrancy, seeded determinism, sweep coverage,
  * FaultyTransport — refusals surface as Table-1 statuses, raise
    actions carry retryable metadata, zero interference when no rule
    matches,
  * the satellite partial-failure property: a producer killed
    mid-``send_burst`` span reservation never exposes a torn or
    reordered span to consumers (SPSC, MPSC fan-in, PriorityTransport),
    deterministic + hypothesis-guarded,
  * pool probes (claim/extend/CoW/swap) leave tables/refcounts/free
    count at pre-op values; quarantine pins private pages forever.
"""
import pytest

try:  # optional dev dependency; property tests skip without it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core import faults, nbb
from repro.core.faults import (ACT_RAISE, ACT_REFUSE, FaultPlan,
                               FaultRule, InjectedFault, recover_ring,
                               stall_mid_burst)
from repro.core.host_queue import MpscQueue, SpscQueue
from repro.core.transport import FaultyTransport, PriorityTransport


# ---------------------------------------------------------------------------
# FaultPlan scheduling
# ---------------------------------------------------------------------------
def test_plan_nth_window():
    plan = FaultPlan([FaultRule("x", nth=2, times=2)])
    assert [plan.fire("x") for _ in range(5)] == \
        [None, ACT_RAISE, ACT_RAISE, None, None]
    assert plan.n_fired == 2 and plan.fired == ["x", "x"]


def test_plan_site_pattern_matches_class():
    plan = FaultPlan([FaultRule("pool.*", nth=1, times=1)])
    assert plan.fire("pool.claim") == ACT_REFUSE    # catalog default
    assert plan.fire("pool.extend") is None         # window consumed
    assert plan.fire("transport.send") is None      # never matched


def test_plan_unmatched_site_never_advances_counter():
    plan = FaultPlan([FaultRule("a", nth=1)])
    for _ in range(10):
        assert plan.fire("b") is None
    assert plan.fire("a") == ACT_RAISE              # still the 1st probe


def test_plan_explicit_action_overrides_default():
    plan = FaultPlan([FaultRule("transport.send", action=ACT_RAISE)])
    assert plan.fire("transport.send") == ACT_RAISE


def test_plan_pause_is_reentrant_and_suppresses_counting():
    plan = FaultPlan([FaultRule("x", nth=1)])
    with plan.pause():
        with plan.pause():
            assert plan.fire("x") is None
        assert plan.fire("x") is None
    # paused probes did not consume the window
    assert plan.fire("x") == ACT_RAISE


def test_plan_random_is_seed_deterministic():
    a = FaultPlan.random(seed=7)
    b = FaultPlan.random(seed=7)
    assert [(r.site, r.nth, r.times) for r in a.rules] == \
        [(r.site, r.nth, r.times) for r in b.rules]


def test_sweep_covers_every_site_class():
    plans = FaultPlan.sweep(50, seed=3)
    pinned = {p.rules[0].site for p in plans}
    assert pinned == set(faults.SITES)
    for p in plans:
        assert all(r.times >= 1 for r in p.rules)


def test_injected_fault_metadata():
    e = InjectedFault("engine.sync", seq=4, retryable=False)
    assert e.site == "engine.sync" and e.seq == 4 and not e.retryable
    assert "engine.sync" in str(e)


# ---------------------------------------------------------------------------
# FaultyTransport refusals
# ---------------------------------------------------------------------------
def test_faulty_transport_passthrough_when_no_rule_matches():
    ring = SpscQueue(4)
    ft = FaultyTransport(ring, FaultPlan([]))
    assert ft.send(1) == nbb.OK
    status, got = ft.try_recv()
    assert (status, got) == (nbb.OK, 1)
    assert ft.send_burst([2, 3]) == (nbb.OK, 2)
    assert ft.drain_burst() == [2, 3]


def test_faulty_transport_send_refusal_is_table1_full():
    ring = SpscQueue(4)
    ft = FaultyTransport(ring, FaultPlan([FaultRule("transport.send")]))
    assert ft.send(1) == nbb.BUFFER_FULL    # refused, nothing inserted
    assert len(ring) == 0
    assert ft.send(2) == nbb.OK             # window consumed: healthy


def test_faulty_transport_recv_refusal_is_table1_empty():
    ring = SpscQueue(4)
    ring.send(9)
    ft = FaultyTransport(ring, FaultPlan([FaultRule("transport.recv")]))
    assert ft.try_recv() == (nbb.BUFFER_EMPTY, None)
    assert ft.try_recv() == (nbb.OK, 9)     # the item was never lost


def test_faulty_transport_raise_action():
    ft = FaultyTransport(SpscQueue(4), FaultPlan(
        [FaultRule("transport.send", action=ACT_RAISE)]))
    with pytest.raises(InjectedFault) as ei:
        ft.send(1)
    assert ei.value.retryable


# ---------------------------------------------------------------------------
# Torn-span safety: producer dies mid-send_burst (the satellite test)
# ---------------------------------------------------------------------------
def _stalled_ring(prefix, dying, capacity=16):
    """A ring holding ``prefix`` committed, then a producer that dies
    mid-burst of ``dying`` (announced, partially written, uncommitted)."""
    ring = SpscQueue(capacity)
    for v in prefix:
        assert ring.send(v) == nbb.OK
    ft = FaultyTransport(ring, FaultPlan([FaultRule("transport.stall")]))
    with pytest.raises(InjectedFault) as ei:
        ft.send_burst(dying)
    assert not ei.value.retryable           # the producer is DEAD
    return ring


def test_spsc_consumer_never_sees_torn_span():
    ring = _stalled_ring([1, 2], [10, 11, 12])
    # Committed prefix only: the announced span is invisible.
    assert len(ring) == 2
    assert ring.drain_burst() == [1, 2]
    assert ring.drain_burst() == []
    # The scalar read on the boundary reports the Table-1 transient
    # status (producer "inserting"), never a torn value.
    status, got = ring.try_recv()
    assert status == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING and got is None


def test_recover_ring_resumes_service():
    ring = _stalled_ring([1], [10, 11])
    assert recover_ring(ring)               # lease owner declares it dead
    assert not recover_ring(ring)           # idempotent
    assert ring.drain_burst() == [1]
    # A new producer reuses the span cleanly — old junk is overwritten.
    assert ring.send_burst([7, 8]) == (nbb.OK, 2)
    assert ring.drain_burst() == [7, 8]


def test_stall_on_full_ring_leaves_it_untouched():
    ring = SpscQueue(2)
    ring.send(1)
    ring.send(2)
    assert stall_mid_burst(ring, [9]) == 0  # died before announcing
    assert not recover_ring(ring)
    assert ring.drain_burst() == [1, 2]


def test_mpsc_dead_producer_does_not_block_siblings():
    q = MpscQueue(3, capacity_per_producer=8)
    q.producer(0).send_burst([1, 2])
    # producer 1 dies mid-span
    ft = FaultyTransport(q.producer(1), FaultPlan(
        [FaultRule("transport.stall")]))
    with pytest.raises(InjectedFault):
        ft.send_burst([66, 67])
    q.producer(2).send_burst([3])
    got = q.drain_burst()
    assert sorted(got) == [1, 2, 3]         # healthy rings fully served
    recover_ring(q.producer(1))
    assert q.producer(1).send_burst([4]) == (nbb.OK, 1)
    assert q.drain_burst() == [4]


def test_priority_transport_dead_class_does_not_corrupt_order():
    pt = PriorityTransport([SpscQueue(8) for _ in range(3)])
    pt.classes[0].send_burst([100])
    ft = FaultyTransport(pt.classes[1], FaultPlan(
        [FaultRule("transport.stall")]))
    with pytest.raises(InjectedFault):
        ft.send_burst([55, 56])
    pt.classes[2].send_burst([300, 301])
    # Priority-ordered drain skips the uncommitted span entirely.
    assert pt.drain_burst() == [100, 300, 301]
    recover_ring(pt.classes[1])
    pt.classes[1].send_burst([200])
    assert pt.drain_burst() == [200]


if given is not None:
    class TestTornSpanProperties:
        @given(prefix=st.lists(st.integers(0, 999), max_size=6),
               dying=st.lists(st.integers(0, 999), min_size=1, max_size=6),
               after=st.lists(st.integers(0, 999), max_size=6),
               capacity=st.integers(2, 8))
        @settings(max_examples=120, deadline=None)
        def test_consumer_sees_committed_prefix_then_recovery(
                self, prefix, dying, after, capacity):
            """For ANY committed prefix, dying span, and post-recovery
            burst: the consumer observes exactly prefix ++ after (FIFO,
            no torn values, no reordering)."""
            ring = SpscQueue(capacity)
            kept = []
            for v in prefix:
                if ring.send(v) == nbb.OK:
                    kept.append(v)
            ft = FaultyTransport(ring, FaultPlan(
                [FaultRule("transport.stall")]))
            try:
                ft.send_burst(dying)
            except InjectedFault:
                pass
            assert ring.drain_burst() == kept   # committed prefix only
            recover_ring(ring)
            status, n = ring.send_burst(after)
            assert ring.drain_burst() == list(after[:n])


# ---------------------------------------------------------------------------
# Pool probes: crash-consistent refusal + quarantine
# ---------------------------------------------------------------------------
def _pool(n_pages=8, page_size=4):
    from repro.serve.kv_cache import PagedKVPool
    return PagedKVPool(n_pages, page_size, n_layers=1, kv_heads=1,
                       head_dim=2)


def test_pool_claim_fault_rolls_back_nothing():
    from repro.serve.kv_cache import OK as POOL_OK, POOL_FULL
    pool = _pool()
    pool.faults = FaultPlan([FaultRule("pool.claim")])
    assert pool.try_admit(1, 8) == POOL_FULL
    assert pool.n_seqs() == 0 and pool.free_pages() == 8
    assert pool.try_admit(1, 8) == POOL_OK      # window consumed
    assert pool.free_pages() == 6


def test_pool_extend_fault_leaves_table_at_preop():
    from repro.serve.kv_cache import OK as POOL_OK, POOL_FULL
    pool = _pool()
    assert pool.try_admit(1, 4) == POOL_OK
    pages_before = list(pool.table(1).pages)
    pool.faults = FaultPlan([FaultRule("pool.extend")])
    assert pool.extend_reservation(1, 16) == POOL_FULL
    assert pool.table(1).pages == pages_before
    assert pool.free_pages() == 7
    assert pool.extend_reservation(1, 16) == POOL_OK
    assert pool.free_pages() == 4


def test_pool_extend_fault_silent_when_no_growth_needed():
    """The probe only fires when pages would actually be claimed — a
    same-size extend (a retried tick's idempotent re-reservation) does
    not consume the fault window."""
    from repro.serve.kv_cache import OK as POOL_OK
    pool = _pool()
    assert pool.try_admit(1, 8) == POOL_OK
    pool.faults = FaultPlan([FaultRule("pool.extend")])
    assert pool.extend_reservation(1, 8) == POOL_OK     # no new pages
    assert pool.faults.n_fired == 0


def test_pool_swap_out_fault_raises_premutation():
    from repro.serve.kv_cache import OK as POOL_OK
    pool = _pool()
    assert pool.try_admit(1, 8) == POOL_OK
    pool.note_tokens(1, 8)
    pages_before = list(pool.table(1).pages)
    pool.faults = FaultPlan([FaultRule("pool.swap_out")])
    with pytest.raises(InjectedFault) as ei:
        pool.swap_out_preempt(1, 8)
    assert ei.value.retryable
    assert pool.table(1).pages == pages_before  # nothing moved
    assert pool.swap_out_bytes == 0
    img = pool.swap_out_preempt(1, 8)           # healthy after window
    assert pool.swap_in_preempt(1, img) == POOL_OK


def test_pool_quarantine_pins_private_pages_forever():
    from repro.serve.kv_cache import OK as POOL_OK
    pool = _pool()
    assert pool.try_admit(1, 8) == POOL_OK      # 2 private pages
    got = pool.quarantine_range(1, 0, 8)
    assert len(got) == 2 and pool.quarantined == set(got)
    assert pool.quarantine_range(1, 0, 8) == [] # idempotent
    pool.free(1)
    # The owner's free dropped its ref, but the quarantine pin holds:
    # the pages stay accounted used and can never be claimed again.
    assert pool.n_seqs() == 0
    assert pool.used_pages() == 2 == len(pool.quarantined)
    assert all(pool.refcount(p) == 1 for p in got)
    # Six pages remain claimable; the quarantined two are never handed out.
    assert pool.try_admit(2, 24) == POOL_OK
    assert set(pool.table(2).pages).isdisjoint(pool.quarantined)


def test_pool_quarantine_skips_shared_pages():
    from repro.serve.kv_cache import OK as POOL_OK
    pool = _pool()
    assert pool.try_admit(1, 8) == POOL_OK
    shared = list(pool.table(1).pages)
    pool.adopt_shared(2, shared, 8)             # second holder
    assert pool.quarantine_range(1, 0, 8) == []
    assert pool.stats()["quarantined"] == 0
