"""Property tests for the OpHandle CAS FSM (PENDING -> COMPLETED|CANCELLED).

The two properties the streaming session API leans on:

  1. *Exactly one terminal state* — any interleaving of concurrent
     ``cancel()`` calls and completion polls lands the handle in exactly
     one of COMPLETED/CANCELLED, and the winner count is exactly one.
  2. *Never double-free* — a resource released on the terminal
     transition (the serving engine's KV slot) is released exactly once
     no matter how the race resolves.

Hypothesis drives randomized interleavings when available; the import is
guarded (requirements-dev.txt), so the suite still collects and the
deterministic/threaded cases still run without it.
"""
import threading

import pytest

try:  # optional dev dependency; property tests skip without it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core import nbb, states
from repro.core.host_queue import SpscQueue
from repro.core.transport import OpHandle


def _spin_barrier(n):
    return threading.Barrier(n, timeout=10)


# ---------------------------------------------------------------------------
# Deterministic single-thread sequences.
# ---------------------------------------------------------------------------
def test_terminal_states_are_absorbing():
    c = states.op_cell()
    assert c.cas(states.OP_PENDING, states.OP_COMPLETED) is True
    assert c.cas(states.OP_PENDING, states.OP_CANCELLED) is False
    assert c.state == states.OP_COMPLETED
    with pytest.raises(states.IllegalTransition):
        c.cas(states.OP_COMPLETED, states.OP_PENDING)


def test_cancel_then_complete_never_completes():
    q = SpscQueue(2)
    h = OpHandle(q.try_recv, "t")
    assert h.cancel()
    q.send("x")
    for _ in range(3):
        assert h.test() is False
    assert h.state == states.OP_CANCELLED and h.result is None


# ---------------------------------------------------------------------------
# Threaded races: exactly one terminal state, exactly one winner.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_cancellers", [1, 2, 4])
def test_concurrent_cancel_vs_completion_single_winner(n_cancellers):
    for _round in range(100):
        q = SpscQueue(2)
        q.send("payload")
        h = OpHandle(q.try_recv, "race")
        barrier = _spin_barrier(n_cancellers + 1)
        cancel_wins = []

        def canceller():
            barrier.wait()
            if h.cancel():
                cancel_wins.append(1)

        def poller():
            barrier.wait()
            h.test()

        ts = ([threading.Thread(target=canceller)
               for _ in range(n_cancellers)]
              + [threading.Thread(target=poller)])
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)
        # exactly one terminal state ...
        assert h.state in (states.OP_COMPLETED, states.OP_CANCELLED)
        # ... and exactly one winner across both sides of the race
        assert len(cancel_wins) == (0 if h.completed else 1)
        # the payload is never lost: completed -> result, cancelled with
        # the pop already committed -> parked in late_result
        if h.completed:
            assert h.result == "payload"
        elif h.attempted_ok:
            assert h.late_result == "payload"
        else:
            assert q.drain() == ["payload"]


def test_concurrent_cancel_vs_completion_never_double_frees():
    """Model the serving engine's KV release: the resource owner frees on
    whichever terminal transition *it* observes won, exactly once."""
    for _round in range(100):
        frees = []
        q = SpscQueue(2)
        q.send("tok")
        h = OpHandle(q.try_recv, "kv")
        barrier = _spin_barrier(2)

        def server():
            barrier.wait()
            # the single resource owner: exactly one free per terminal
            if h.test():
                frees.append("completed")
            elif h.cancelled:
                frees.append("cancelled")
            else:                       # still pending: poll to terminal
                while not h.test() and not h.cancelled:
                    pass
                frees.append("completed" if h.completed else "cancelled")

        def client():
            barrier.wait()
            h.cancel()

        ts = [threading.Thread(target=server), threading.Thread(target=client)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)
        assert len(frees) == 1, frees
        assert frees[0] == ("completed" if h.completed else "cancelled")


# ---------------------------------------------------------------------------
# Hypothesis: randomized interleavings of poll/cancel micro-ops.
# ---------------------------------------------------------------------------
if st is not None:

    @settings(max_examples=200, deadline=None)
    @given(ops=st.lists(st.sampled_from(["poll", "cancel", "feed"]),
                        min_size=1, max_size=24))
    def test_any_op_sequence_lands_in_at_most_one_terminal(ops):
        """Arbitrary sequential interleaving (the linearized form of any
        concurrent schedule): at most one terminal state, transitions
        never go terminal -> anything, results consistent with the FSM."""
        q = SpscQueue(4)
        h = OpHandle(q.try_recv, "prop")
        seen_states = [h.state]
        completions, cancel_wins = 0, 0
        for op in ops:
            if op == "feed":
                q.send("v")
            elif op == "poll":
                if h.test():
                    completions += 1
            else:
                if h.cancel():
                    cancel_wins += 1
            seen_states.append(h.state)
        # terminal states are absorbing along the whole trajectory
        for a, b in zip(seen_states, seen_states[1:]):
            if a != states.OP_PENDING:
                assert b == a
        assert cancel_wins <= 1
        if h.completed:
            assert cancel_wins == 0 and h.result == "v"
        if h.cancelled:
            assert completions == 0 and cancel_wins == 1

    @settings(max_examples=100, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1),
           n_cancellers=st.integers(min_value=1, max_value=3))
    def test_threaded_race_property(seed, n_cancellers):
        """Same exactly-one-terminal/never-double-free property under real
        threads, with hypothesis choosing the contention shape."""
        q = SpscQueue(2)
        q.send(seed)
        h = OpHandle(q.try_recv, "prop-race")
        barrier = _spin_barrier(n_cancellers + 1)
        frees = []

        def canceller():
            barrier.wait()
            h.cancel()

        def owner():
            barrier.wait()
            while not h.test() and not h.cancelled:
                pass
            frees.append(h.state)       # the one release point

        ts = ([threading.Thread(target=canceller)
               for _ in range(n_cancellers)]
              + [threading.Thread(target=owner)])
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)
        assert len(frees) == 1
        assert frees[0] in (states.OP_COMPLETED, states.OP_CANCELLED)
        assert frees[0] == h.state
        if h.completed:
            assert h.result == seed
        elif not h.attempted_ok:
            assert q.drain() == [seed]  # payload not consumed

else:  # pragma: no cover - exercised only without hypothesis installed

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_op_sequence_lands_in_at_most_one_terminal():
        pass


# ---------------------------------------------------------------------------
# The OK statuses stay Table-1 compatible through the handle layer.
# ---------------------------------------------------------------------------
def test_last_status_reports_table1_codes():
    q = SpscQueue(1)
    h = OpHandle(lambda: (q.send("x"), None), "s")
    assert h.test() is True
    h2 = OpHandle(lambda: (q.send("y"), None), "s2")
    assert h2.test() is False
    assert h2.last_status == nbb.BUFFER_FULL
    h3 = OpHandle(q.try_recv, "r")
    assert h3.test() is True and h3.result == "x"
    h4 = OpHandle(q.try_recv, "r2")
    assert h4.test() is False
    assert h4.last_status == nbb.BUFFER_EMPTY
