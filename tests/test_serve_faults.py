"""Self-healing serve loop under injected faults (DESIGN.md §13).

The engine half of the tentpole: the tick watchdog (transient retry,
then per-slot typed terminals — never a raise out of ``tick()``),
per-session leases reclaiming a silent client's whole stake, poisoned
writes quarantining their pages, dead-engine handles resolving with a
typed falsy FailedStatus instead of hanging, Session.close semantics,
and the acceptance sweep: 50 seeded plans, every site class hit,
survivors byte-identical to the no-fault run, pool/refcount/prefix
invariants exact after every plan.
"""
import time

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_smoke_config  # noqa: E402
from repro.core import faults, states  # noqa: E402
from repro.core.faults import FaultPlan, FaultRule  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import FailedStatus, ServeEngine  # noqa: E402
from repro.serve.overload import OverloadPolicy  # noqa: E402


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk(model, params, fault_plan=None, lease_s=None, tick_retries=1,
        overload=None, max_batch=2, pool_pages=24, n_clients=2):
    return ServeEngine(model, params, max_batch=max_batch, max_len=64,
                       n_clients=n_clients, pool_pages=pool_pages,
                       page_size=8, scheduler="slot_paged", k_max=4,
                       chunk_tokens=16, overload=overload,
                       fault_plan=fault_plan, lease_s=lease_s,
                       tick_retries=tick_retries)


def _share_jit(eng, donor):
    """Adopt a donor engine's compiled-function caches (identical model
    + shapes), so a many-engine sweep compiles each trace once."""
    eng._jit_loops = donor._jit_loops
    eng._jit_chunked = donor._jit_chunked
    eng._jit_prefill = donor._jit_prefill
    eng._jit_decode = donor._jit_decode
    eng._jit_write_slot = donor._jit_write_slot
    eng.pool._cow_fns = donor.pool._cow_fns
    eng.pool._swap_fns = donor.pool._swap_fns


def _drive(eng, handles, max_ticks=800):
    """Tick the engine inline until every handle is terminal.  The
    tick budget IS the no-deadlock assertion: a fault plan that wedges
    the engine (or strands a handle) fails here, not by hanging CI."""
    ticks = 0
    while not all(h.test() for h in handles):
        ticks += 1
        assert ticks < max_ticks, (
            f"engine wedged: {sum(h.test() for h in handles)}/"
            f"{len(handles)} terminal after {max_ticks} ticks")
        eng.tick()
    return ticks


def _pool_clean(eng):
    """Post-drain pool invariants (the crash-consistency acceptance):
    every page is either free or quarantined, no sequence survives, and
    the copy-traffic ledger balances exactly."""
    pool = eng.pool
    if eng.prefix_cache is not None:
        eng.prefix_cache.clear()
    assert pool.n_seqs() == 0, pool._tables
    assert pool.used_pages() == len(pool.quarantined)
    assert all(pool.refcount(p) == 1 for p in pool.quarantined)
    assert pool.kv_copy_bytes == (pool.cow_copy_bytes
                                  + pool.swap_in_bytes
                                  + pool.swap_out_bytes)


# ---------------------------------------------------------------------------
# FailedStatus + ctor validation
# ---------------------------------------------------------------------------
def test_failed_status_is_falsy_with_reason():
    s = FailedStatus("tick failed: boom")
    assert not s and s.reason == "tick failed: boom"


def test_ctor_validates_robustness_knobs(engine_setup):
    _, model, params = engine_setup
    with pytest.raises(ValueError):
        _mk(model, params, lease_s=0.0)
    with pytest.raises(ValueError):
        _mk(model, params, tick_retries=-1)


def test_no_plan_means_no_fault_layer(engine_setup):
    _, model, params = engine_setup
    eng = _mk(model, params)
    assert eng.faults is None and eng.pool.faults is None
    # rings are bare — the zero-overhead claim is structural
    assert not hasattr(eng.streams[0], "plan")


# ---------------------------------------------------------------------------
# Tick watchdog
# ---------------------------------------------------------------------------
def test_transient_dispatch_fault_is_invisible(engine_setup):
    """One injected dispatch refusal within the retry budget: the tick
    retries and the token stream is byte-identical to the no-fault run."""
    cfg, model, params = engine_setup
    prompt = np.arange(8) % cfg.vocab_size

    eng = _mk(model, params)
    h = eng.connect(0).submit_i(prompt, max_tokens=8)
    _drive(eng, [h])
    ref = h.response.tokens_out.copy()

    plan = FaultPlan([FaultRule("engine.dispatch", nth=1, times=1)])
    eng = _mk(model, params, fault_plan=plan, tick_retries=1)
    _share_jit(eng, _mk(model, params))
    h = eng.connect(0).submit_i(prompt, max_tokens=8)
    _drive(eng, [h])
    assert plan.n_fired == 1
    assert eng.stats["faults_injected"] == 1
    assert eng.stats["requests_failed"] == 0
    np.testing.assert_array_equal(h.response.tokens_out, ref)
    _pool_clean(eng)


def test_dispatch_retries_exhausted_fails_slots_keeps_serving(engine_setup):
    """Past ``tick_retries`` consecutive dispatch faults the bound slots
    fail with typed terminals — and the NEXT request is served normally
    on the same engine (self-healing, not fail-stop)."""
    cfg, model, params = engine_setup
    prompt = np.arange(8) % cfg.vocab_size
    # two firings: the first tick faults, its single retry faults again
    plan = FaultPlan([FaultRule("engine.dispatch", nth=1, times=2)])
    eng = _mk(model, params, fault_plan=plan, tick_retries=1)
    sess = eng.connect(0)
    h = sess.submit_i(prompt, max_tokens=8)
    _drive(eng, [h])
    r = h.response
    assert r.fsm.state == states.REQUEST_CANCELLED
    assert isinstance(r.status, FailedStatus) and "tick failed" in \
        r.status.reason
    assert eng.stats["requests_failed"] == 1
    assert eng.dead is None                     # the ENGINE survived
    h2 = sess.submit_i(prompt, max_tokens=4)    # plan quiet: healthy now
    _drive(eng, [h2])
    assert h2.response.fsm.state == states.REQUEST_COMPLETED
    _pool_clean(eng)


def test_sync_timeout_is_not_retried(engine_setup):
    """engine.sync is non-retryable (the device advanced past what the
    host harvested): the slot fails on the FIRST fault even with a
    generous retry budget."""
    cfg, model, params = engine_setup
    plan = FaultPlan([FaultRule("engine.sync", nth=1, times=1)])
    eng = _mk(model, params, fault_plan=plan, tick_retries=10)
    h = eng.connect(0).submit_i(np.arange(8) % cfg.vocab_size, max_tokens=8)
    _drive(eng, [h])
    assert isinstance(h.response.status, FailedStatus)
    assert eng.stats["requests_failed"] == 1
    assert plan.n_fired == 1                    # no retry consumed more
    _pool_clean(eng)


def test_poisoned_write_quarantines_pages(engine_setup):
    """A poisoned page write fails the slot AND pins the implicated
    private pages out of circulation forever: later admissions never
    receive them, and the pool accounts them used."""
    cfg, model, params = engine_setup
    plan = FaultPlan([FaultRule("pool.page_write", nth=1, times=1)])
    eng = _mk(model, params, fault_plan=plan)
    sess = eng.connect(0)
    h = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=8)
    _drive(eng, [h])
    assert isinstance(h.response.status, FailedStatus)
    assert "poisoned" in h.response.status.reason
    quarantined = set(eng.pool.quarantined)
    assert quarantined and eng.stats["pages_quarantined"] == len(quarantined)
    h2 = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=8)
    _drive(eng, [h2])
    assert h2.response.fsm.state == states.REQUEST_COMPLETED
    assert set(eng.pool.quarantined) == quarantined   # still pinned
    _pool_clean(eng)
    assert eng.pool.used_pages() == len(quarantined)


def test_preempt_fault_leaves_victim_decoding(engine_setup):
    """An injected pool.swap_out fault aborts the preemption attempt
    pre-mutation: the victim keeps decoding to completion and the
    high-priority arrival simply waits (no lost request, no leak)."""
    cfg, model, params = engine_setup
    ov = OverloadPolicy(priorities=True, preemption=True)
    plan = FaultPlan([FaultRule("pool.swap_out", nth=1, times=99)])
    # pool sized so the second admission needs a victim
    eng = _mk(model, params, fault_plan=plan, overload=ov, max_batch=1,
              pool_pages=5)
    lo = eng.connect(0).submit_i(np.arange(8) % cfg.vocab_size,
                                 max_tokens=16, priority=2)
    hi = eng.connect(1).submit_i((np.arange(6) + 3) % cfg.vocab_size,
                                 max_tokens=4, priority=0)
    _drive(eng, [lo, hi])
    assert lo.response.fsm.state == states.REQUEST_COMPLETED
    assert hi.response.fsm.state == states.REQUEST_COMPLETED
    assert eng.stats["preemptions"] == 0        # every attempt refused
    _pool_clean(eng)


def test_stalled_stream_producer_recovers(engine_setup):
    """transport.stall on the engine's own stream ring: the watchdog
    rolls the announced-but-uncommitted span back (the engine IS the
    producer), fails the bound slots, and keeps serving — the stream
    ring works again afterwards."""
    cfg, model, params = engine_setup
    plan = FaultPlan([FaultRule("transport.stall", nth=1, times=1)])
    eng = _mk(model, params, fault_plan=plan)
    sess = eng.connect(0)
    h = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=8)
    _drive(eng, [h])
    assert isinstance(h.response.status, FailedStatus)
    assert not eng._raw_ring(eng.streams[0])._uc & 1    # recovered
    h2 = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=6)
    _drive(eng, [h2])
    r2 = h2.response
    assert r2.fsm.state == states.REQUEST_COMPLETED
    assert len(r2.tokens_out) == 6
    _pool_clean(eng)


# ---------------------------------------------------------------------------
# Leases
# ---------------------------------------------------------------------------
def test_lease_reaps_silent_client(engine_setup):
    """A client that submits and never pumps again: past ``lease_s`` its
    bound slot fails, its queued submission drains, its pages free, and
    the already-delivered terminals carry FailedStatus when it finally
    pumps.  A healthy client on the same engine is untouched."""
    cfg, model, params = engine_setup
    eng = _mk(model, params, lease_s=0.05)
    dead_sess = eng.connect(0)
    live_sess = eng.connect(1)
    h_bound = dead_sess.submit_i(np.arange(8) % cfg.vocab_size,
                                 max_tokens=32)
    eng.tick()                                  # binds + starts decoding
    h_queued = dead_sess.submit_i(np.arange(8) % cfg.vocab_size,
                                  max_tokens=8)
    time.sleep(0.08)                            # client goes silent
    h_live = live_sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=4)
    served = 0
    for _ in range(200):
        served += eng.tick()[0]
        if h_live.test() and eng.stats["leases_reaped"]:
            break
    assert eng.stats["leases_reaped"] == 1      # one sweep took everything
    assert eng.stats["requests_failed"] == 2
    assert eng.pool.n_seqs() == 0          # the reaped stake is reclaimed
    assert h_live.response.fsm.state == states.REQUEST_COMPLETED
    # the silent client comes back: terminals resolve, typed + falsy
    for h in (h_bound, h_queued):
        r = h.wait(timeout_s=5)
        assert r.fsm.state == states.REQUEST_CANCELLED
        assert isinstance(r.status, FailedStatus)
        assert "lease expired" in r.status.reason
    _pool_clean(eng)


def test_lease_renewed_by_pumping_client(engine_setup):
    """A slow-but-pumping client is NEVER reaped: every wait() poll is a
    heartbeat."""
    cfg, model, params = engine_setup
    eng = _mk(model, params, lease_s=0.05)
    h = eng.connect(0).submit_i(np.arange(8) % cfg.vocab_size,
                                max_tokens=8)
    ticks = 0
    while not h.test():                         # test() pumps = heartbeat
        time.sleep(0.002)
        eng.tick()
        ticks += 1
        assert ticks < 800
    assert h.response.fsm.state == states.REQUEST_COMPLETED
    assert eng.stats["leases_reaped"] == 0
    _pool_clean(eng)


def test_lease_recovers_stalled_intake_ring(engine_setup):
    """The one failure a refusal can't model: the client thread died
    BETWEEN announcing and committing an intake span.  The lease reaper
    rolls the ring back (the lease declared the producer dead) and the
    ring serves a reconnecting client again."""
    cfg, model, params = engine_setup
    eng = _mk(model, params, lease_s=0.05)
    sess = eng.connect(0)
    ring = eng.intake.producer(0)
    sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=4)
    faults.stall_mid_burst(ring, [object()])    # died mid-reservation
    assert ring._uc & 1
    time.sleep(0.08)
    for _ in range(50):
        eng.tick()
        if eng.stats["leases_reaped"]:
            break
    assert not ring._uc & 1                     # rolled back by the reaper
    assert eng.stats["leases_reaped"] == 1
    # reconnect: the ring is fully serviceable again
    sess2 = eng.connect(0)
    h = sess2.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=4)
    _drive(eng, [h])
    assert h.response.fsm.state == states.REQUEST_COMPLETED
    _pool_clean(eng)


# ---------------------------------------------------------------------------
# Session.close + dead-engine handles
# ---------------------------------------------------------------------------
def test_session_close_cancels_and_refuses(engine_setup):
    cfg, model, params = engine_setup
    eng = _mk(model, params)
    sess = eng.connect(0)
    h = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=32)
    eng.tick()
    sess.close()
    sess.close()                                # idempotent
    assert sess.closed
    # the engine retires the cancelled slot on its next ticks
    for _ in range(50):
        eng.tick()
        if eng.pool.n_seqs() == 0:
            break
    assert eng.pool.n_seqs() == 0
    assert eng.pool.free_pages() == eng.pool.n_pages
    # submit after close: already-terminal typed handle, no engine work
    h2 = sess.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=4)
    assert h2.done and isinstance(h2.status, FailedStatus)
    assert h2.status.reason == "session closed"
    assert h2.response.fsm.state == states.REQUEST_CANCELLED
    # context-manager form + reconnect reopens
    with eng.connect(0) as sess3:
        assert not sess3.closed
        h3 = sess3.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=4)
        _drive(eng, [h3])
        assert h3.response.fsm.state == states.REQUEST_COMPLETED
    assert sess3.closed


def test_dead_engine_resolves_handles_fast(engine_setup):
    """Satellite 1: wait()/get_response on a dead engine return a typed
    falsy FailedStatus promptly — never hang out the timeout."""
    cfg, model, params = engine_setup
    eng = _mk(model, params)
    sess = eng.connect(0)
    h = sess.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=32)
    eng.tick()
    eng._die("engine loop crashed: test")
    t0 = time.monotonic()
    r = h.wait(timeout_s=30)
    assert time.monotonic() - t0 < 5            # resolved, not timed out
    assert isinstance(r, FailedStatus) and not r
    assert "crashed" in r.reason
    # whole-response surface too
    t0 = time.monotonic()
    r2 = eng.get_response(0, timeout_s=30)
    assert time.monotonic() - t0 < 5
    assert not r2
    # a post-death submit also resolves instead of hanging
    h2 = sess.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=4)
    assert isinstance(h2.wait(timeout_s=5), FailedStatus)
    assert eng.pool.n_seqs() == 0               # _die reclaimed the pool
    _pool_clean(eng)


def test_tick_after_death_is_inert(engine_setup):
    _, model, params = engine_setup
    eng = _mk(model, params)
    eng._die("x")
    assert eng.tick() == (0, False)
    assert eng.dead == "x"


# ---------------------------------------------------------------------------
# The acceptance sweep: 50 seeded plans, survivors byte-identical
# ---------------------------------------------------------------------------
def test_fault_plan_sweep_engine_never_wedges(engine_setup):
    """ISSUE 8 acceptance: under a seeded 50-plan sweep covering every
    site class, the engine never deadlocks or raises out of tick(),
    every surviving (COMPLETED) request's tokens are byte-identical to
    the no-fault run, and the pool invariants hold after every plan."""
    cfg, model, params = engine_setup
    ov = OverloadPolicy(priorities=True, preemption=True)
    prompts = [(np.arange(8) + 3 * i) % cfg.vocab_size for i in range(4)]
    pris = [2, 0, 1, 0]
    budgets = [12, 4, 6, 4]

    def run(fault_plan, donor=None):
        eng = _mk(model, params, fault_plan=fault_plan, overload=ov,
                  tick_retries=1, max_batch=2, pool_pages=8)
        if donor is not None:
            _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = [sessions[i % 2].submit_i(p, max_tokens=budgets[i],
                                            priority=pris[i])
                   for i, p in enumerate(prompts)]
        _drive(eng, handles, max_ticks=800)
        _pool_clean(eng)
        return eng, handles

    donor, ref_handles = run(None)
    ref = {i: h.response.tokens_out.copy()
           for i, h in enumerate(ref_handles)}
    assert all(h.response.fsm.state == states.REQUEST_COMPLETED
               for h in ref_handles)

    hit_sites = set()
    for plan in FaultPlan.sweep(50, seed=11):
        eng, handles = run(plan, donor=donor)
        hit_sites.update(plan.fired)
        assert eng.dead is None, (plan, eng.dead)
        for i, h in enumerate(handles):
            r = h.response
            if r.fsm.state == states.REQUEST_COMPLETED:
                np.testing.assert_array_equal(r.tokens_out, ref[i], plan)
            else:
                assert r.fsm.state == states.REQUEST_CANCELLED
        s = eng.stats
        terminal = (s["served"] + s["rejected"] + s["cancelled"]
                    + s["shed_requests"] + s["requests_failed"])
        assert terminal >= len(handles)
    # every site CLASS reachable here was exercised somewhere in the
    # sweep.  The ISSUE-9 snapshot/journal sites only probe on an
    # engine with snapshot_dir armed — their sweep coverage lives in
    # tests/test_serve_recovery.py and benchmarks/bench_faults.py
    # (where every plan crosses a kill-restore boundary).
    assert {s.split(".")[0] for s in hit_sites} == \
        {s.split(".")[0] for s in faults.SITES} - {"snapshot", "journal"}
