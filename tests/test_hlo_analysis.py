"""Unit tests for the while-aware HLO accountant against hand-built HLO
and against a real jitted program's known FLOP count."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def test_dot_flops_counted():
    def f(a, b):
        return a @ b

    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    acc = H.analyze(hlo)
    assert acc.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_while_trip_multiplication():
    """A fori_loop of k matmuls must count k * one-matmul flops."""
    a = jnp.zeros((64, 64), jnp.float32)

    def f(a):
        return jax.lax.fori_loop(0, 17, lambda i, x: x @ x, a)

    hlo = jax.jit(f).lower(a).compile().as_text()
    acc = H.analyze(hlo)
    one = 2 * 64 * 64 * 64
    assert acc.flops == pytest.approx(17 * one, rel=0.05)


def test_scan_over_layers_like_model():
    """scan over stacked weights — the model zoo's layer pattern."""
    ws = jnp.zeros((12, 32, 32), jnp.float32)
    x = jnp.zeros((8, 32), jnp.float32)

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    acc = H.analyze(hlo)
    assert acc.flops == pytest.approx(12 * 2 * 8 * 32 * 32, rel=0.05)


def test_bytes_reasonable_for_big_matmul():
    """bytes ~ operands + output at fusion boundaries, not per-HLO-op."""
    a = jnp.zeros((512, 512), jnp.bfloat16)
    hlo = jax.jit(lambda a, b: a @ b).lower(a, a).compile().as_text()
    acc = H.analyze(hlo)
    ideal = 3 * 512 * 512 * 2
    # compiled program adds layout copies around the dot; operand-name
    # resolution counts them, so allow up to 6x the algorithmic minimum
    assert ideal <= acc.bytes <= 6 * ideal


def test_parse_finds_entry():
    hlo = jax.jit(lambda x: x + 1).lower(jnp.zeros((4,))).compile().as_text()
    comps, entry = H.parse_hlo(hlo)
    assert entry is not None and entry in comps
