"""Pipeline + compression: single-device numerics here; the 8-device
schedule equivalence / collective-bytes checks run in a subprocess
(tests/_multidevice_worker.py) so the forced device count never leaks
into this process.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.parallel.compression import (compress_grads, decompress_grads,
                                        dequantize_int8, init_error_state,
                                        quantize_int8)

jax.config.update("jax_platform_name", "cpu")


def test_multidevice_worker():
    """Run pipeline schedule equivalence + compressed psum on 8 devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).with_name("_multidevice_worker.py"))],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout


# ---------------------------------------------------------------------------
# compression numerics (single device)
# ---------------------------------------------------------------------------
if st is None:
    def test_quantize_roundtrip_bounded():
        pytest.importorskip("hypothesis")  # records the skip with reason
else:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_quantize_roundtrip_bounded(seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,), jnp.float32)
        q, scale = quantize_int8(x)
        recon = dequantize_int8(q, scale)
        err = np.abs(np.asarray(x) - np.asarray(recon)).max()
        assert err <= float(scale) / 2 + 1e-7


def test_quantize_zero_tensor():
    q, scale = quantize_int8(jnp.zeros((8,)))
    assert float(scale) == 1.0
    np.testing.assert_array_equal(np.asarray(q), 0)


def test_error_feedback_telescopes():
    """sum of k compressed steps -> k*g with O(1) (not O(k)) error."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,), jnp.float32)}
    err = init_error_state(g)
    total = jnp.zeros((128,))
    k = 20
    for _ in range(k):
        comp, err = compress_grads(g, err)
        total = total + decompress_grads(comp)["w"]
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    resid = np.abs(np.asarray(total) - k * np.asarray(g["w"])).max()
    assert resid <= scale + 1e-6, (resid, scale)


def test_compression_ratio():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    comp, _ = compress_grads(g, init_error_state(g))
    raw = 1024 * 4
    packed = comp["w"]["q"].size * 1 + 4
    assert packed * 3 < raw
