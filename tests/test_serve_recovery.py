"""Crash recovery (DESIGN.md §14): crash-consistent snapshots, the
write-ahead intake journal, and byte-identical stream resumption.

The acceptance battery for ISSUE 9:
- kill-at-every-tick sweep: abandon the engine at EVERY tick boundary
  of the reference run, restore a fresh engine from the snapshot +
  journal, re-bind the live handles — every stream must come out
  byte-identical to the uninterrupted run, delivered exactly once;
- snapshot→restore roundtrip property test (hypothesis): the restored
  pool is EXACTLY the captured pool — refcounts, block tables, free
  ledger, page bytes, and the copy-traffic ledger
  (``kv_copy == cow + swap_in + swap_out``) — and the resumed engine
  finishes every request with the same tokens;
- torn snapshot writes (injected ``snapshot.write`` fault) never cost
  the previous good snapshot; a lost journal record (``journal.append``
  fault) fails its handle typed ("lost across restart"), never hangs;
- ``serve_forever(restart=True)`` self-restarts across a loop crash;
- Session reconnect semantics: ``connect(resume=...)`` adoption,
  idempotent close, terminal re-delivery deduped (exactly-once).
"""
import threading
import time

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

try:  # optional dev dependency (requirements-dev.txt); property tests
    from hypothesis import given, settings, strategies as st  # skip without it
except ImportError:
    given = settings = st = None

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.faults import FaultPlan, FaultRule  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve import snapshot as snapshot_mod  # noqa: E402
from repro.serve.engine import FailedStatus, ServeEngine  # noqa: E402
from repro.serve.snapshot import SnapshotError  # noqa: E402

MAX_TICKS = 800


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    donor = _mk(model, params)
    return cfg, model, params, donor


def _mk(model, params, snapshot_dir=None, snapshot_every=None,
        fault_plan=None, pool_pages=24):
    return ServeEngine(model, params, max_batch=2, max_len=64,
                       n_clients=2, pool_pages=pool_pages, page_size=8,
                       scheduler="slot_paged", k_max=4, chunk_tokens=16,
                       fault_plan=fault_plan, tick_retries=1,
                       snapshot_dir=snapshot_dir,
                       snapshot_every=snapshot_every)


def _share_jit(eng, donor):
    """Adopt the donor's compiled-function caches (identical shapes):
    the whole module compiles each trace exactly once."""
    eng._jit_loops = donor._jit_loops
    eng._jit_chunked = donor._jit_chunked
    eng._jit_prefill = donor._jit_prefill
    eng._jit_decode = donor._jit_decode
    eng._jit_write_slot = donor._jit_write_slot
    eng.pool._cow_fns = donor.pool._cow_fns
    eng.pool._swap_fns = donor.pool._swap_fns


def _submit_all(sessions, vocab, n=4, max_tokens=12, seed=3):
    rng = np.random.default_rng(seed)
    return [sessions[i % len(sessions)].submit_i(
                rng.integers(0, 1000, 6) % vocab, max_tokens=max_tokens)
            for i in range(n)]


def _drive(eng, handles, max_ticks=MAX_TICKS):
    ticks = 0
    while not all(h.test() for h in handles):
        ticks += 1
        assert ticks < max_ticks, (
            f"wedged: {sum(h.test() for h in handles)}/{len(handles)} "
            f"terminal after {max_ticks} ticks")
        eng.tick()
    return ticks


def _tokens_of(handles):
    return [list(map(int, h.response.tokens_out)) for h in handles]


def _pool_clean(eng):
    pool = eng.pool
    if eng.prefix_cache is not None:
        eng.prefix_cache.clear()
    assert pool.n_seqs() == 0
    assert pool.used_pages() == len(pool.quarantined)
    assert pool.kv_copy_bytes == (pool.cow_copy_bytes
                                  + pool.swap_in_bytes
                                  + pool.swap_out_bytes)


def _run_reference(model, params, donor, vocab, **wl):
    eng = _mk(model, params)
    _share_jit(eng, donor)
    sessions = [eng.connect(c) for c in range(2)]
    handles = _submit_all(sessions, vocab, **wl)
    ticks = _drive(eng, handles)
    assert all(h.response.fsm.state.endswith("COMPLETED") for h in handles)
    return _tokens_of(handles), ticks


def _run_killed(model, params, donor, vocab, kill_tick, tmpdir,
                fault_plan=None, **wl):
    """Drive to ``kill_tick``, abandon the engine (final snapshot
    attempt), restore a fresh one from disk, re-bind the sessions, and
    finish.  Returns (final_engine, handles)."""
    d = str(tmpdir)
    eng = _mk(model, params, snapshot_dir=d, fault_plan=fault_plan)
    _share_jit(eng, donor)
    sessions = [eng.connect(c) for c in range(2)]
    handles = _submit_all(sessions, vocab, **wl)
    ticks = 0
    killed = False
    while not all(h.test() for h in handles):
        ticks += 1
        assert ticks < MAX_TICKS
        eng.tick()
        if not killed and ticks >= kill_tick:
            killed = True
            eng.save_snapshot()
            for s in sessions:
                s.pump()            # clients keep what their rings committed
            eng2 = _mk(model, params, snapshot_dir=d, fault_plan=fault_plan)
            _share_jit(eng2, donor)
            eng2.restore_latest()
            sessions = [eng2.connect(c, resume=s)
                        for c, s in enumerate(sessions)]
            eng = eng2
    return eng, handles


# ---------------------------------------------------------------------------
# Kill-at-every-tick: byte-identical resumption from any boundary
# ---------------------------------------------------------------------------
class TestKillAtEveryTick:
    def test_every_boundary_resumes_byte_identical(self, engine_setup,
                                                   tmp_path):
        cfg, model, params, donor = engine_setup
        ref_tokens, ref_ticks = _run_reference(model, params, donor,
                                               cfg.vocab_size)
        assert ref_ticks >= 3, "workload too small to exercise boundaries"
        for t in range(1, ref_ticks + 1):
            eng, handles = _run_killed(model, params, donor,
                                       cfg.vocab_size, t,
                                       tmp_path / f"kill{t}")
            states_out = [h.response.fsm.state.split("_")[-1]
                          for h in handles]
            assert states_out == ["COMPLETED"] * len(handles), \
                f"kill@{t}: {states_out}"
            assert _tokens_of(handles) == ref_tokens, \
                f"kill@{t}: streams diverged"
            # Exactly-once: the streamed positions (client-side dedupe
            # over pre-kill ring deliveries + post-restore re-streams)
            # cover every position exactly once, values matching the
            # terminal output.
            for h, ref in zip(handles, ref_tokens):
                got = sorted(h.tokens(timeout_s=5))
                assert got == list(enumerate(ref)), f"kill@{t}"
            _pool_clean(eng)

    def test_restore_reports_resumed_work(self, engine_setup, tmp_path):
        cfg, model, params, donor = engine_setup
        d = str(tmp_path / "report")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit_all(sessions, cfg.vocab_size, max_tokens=24)
        for _ in range(4):
            eng.tick()
        assert eng.save_snapshot() is not None
        eng2 = _mk(model, params, snapshot_dir=d)
        _share_jit(eng2, donor)
        report = eng2.restore_latest()
        assert report is not None and report["resumed"] >= 2
        assert eng2.stats["restores"] == 1
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        _drive(eng2, handles)
        assert all(h.response.fsm.state.endswith("COMPLETED")
                   for h in handles)


# ---------------------------------------------------------------------------
# The write-ahead intake journal
# ---------------------------------------------------------------------------
class TestJournalReplay:
    def test_binds_after_snapshot_replay_deterministically(
            self, engine_setup, tmp_path):
        cfg, model, params, donor = engine_setup
        ref_tokens, _ = _run_reference(model, params, donor,
                                       cfg.vocab_size, n=4, max_tokens=8)
        d = str(tmp_path / "wal")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, 1000, 6) % cfg.vocab_size
                   for _ in range(4)]
        handles = [sessions[i % 2].submit_i(prompts[i], max_tokens=8)
                   for i in range(2)]
        for _ in range(2):
            eng.tick()
        assert eng.save_snapshot() is not None
        # These two submissions postdate the snapshot: their only
        # recovery story is the WAL.
        handles += [sessions[i % 2].submit_i(prompts[i], max_tokens=8)
                    for i in range(2, 4)]
        ticks = 0
        while eng._journal.seq < 4:     # drive until both are BOUND
            ticks += 1
            assert ticks < MAX_TICKS
            eng.tick()
        for s in sessions:
            s.pump()
        eng2 = _mk(model, params, snapshot_dir=d)
        _share_jit(eng2, donor)
        report = eng2.restore_latest()
        assert report is not None and report["replayed"] == 2
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        _drive(eng2, handles)
        assert _tokens_of(handles) == ref_tokens
        _pool_clean(eng2)

    def test_lost_journal_record_fails_typed_not_hangs(
            self, engine_setup, tmp_path):
        cfg, model, params, donor = engine_setup
        # Third bind's WAL append is injected away: that request cannot
        # be replayed after the crash — its handle must resolve with the
        # typed falsy FailedStatus, not hang.
        plan = FaultPlan([FaultRule("journal.append", nth=3)])
        d = str(tmp_path / "lostrec")
        eng = _mk(model, params, snapshot_dir=d, fault_plan=plan)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit_all(sessions, cfg.vocab_size, n=2, max_tokens=4)
        for _ in range(2):
            eng.tick()
        assert eng.save_snapshot() is not None
        h3 = sessions[0].submit_i(
            np.arange(6, dtype=np.int32) % cfg.vocab_size, max_tokens=24)
        ticks = 0
        while not any(s.request is not None
                      and s.request.req_id == h3.req_id
                      for s in eng.slots):
            ticks += 1
            assert ticks < MAX_TICKS
            eng.tick()
        assert eng._journal.seq == 2    # the bind really was lost
        for s in sessions:
            s.pump()
        eng2 = _mk(model, params, snapshot_dir=d, fault_plan=plan)
        _share_jit(eng2, donor)
        assert eng2.restore_latest() is not None
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        assert h3.test(), "unreplayable handle must finalize at re-bind"
        assert isinstance(h3.status, FailedStatus) and not h3.status
        assert "lost across restart" in h3.status.reason
        _drive(eng2, handles)
        assert all(h.response.fsm.state.endswith("COMPLETED")
                   for h in handles)


# ---------------------------------------------------------------------------
# Torn writes and aborted restores
# ---------------------------------------------------------------------------
class TestTornSnapshots:
    def test_torn_write_never_corrupts_last_good(self, engine_setup,
                                                 tmp_path):
        cfg, model, params, donor = engine_setup
        plan = FaultPlan([FaultRule("snapshot.write", nth=2)])
        d = str(tmp_path / "torn")
        eng = _mk(model, params, snapshot_dir=d, fault_plan=plan)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit_all(sessions, cfg.vocab_size, max_tokens=24)
        for _ in range(3):
            eng.tick()
        good = eng.save_snapshot()
        assert good is not None
        for _ in range(2):
            eng.tick()
        assert eng.save_snapshot() is None      # injected tear
        torn = [p for p in snapshot_mod._snap_paths(d) if p != good]
        assert torn, "the torn write must still have left a file"
        with pytest.raises(SnapshotError):
            snapshot_mod.read_snapshot(torn[0])
        snap, path = snapshot_mod.load_latest(d)
        assert path == good                     # fallback, not corruption
        for s in sessions:
            s.pump()
        eng2 = _mk(model, params, snapshot_dir=d)
        _share_jit(eng2, donor)
        assert eng2.restore_latest() is not None
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        _drive(eng2, handles)
        assert all(h.response.fsm.state.endswith("COMPLETED")
                   for h in handles)
        _pool_clean(eng2)

    def test_restore_fault_retries_then_gives_up_typed(self, engine_setup,
                                                       tmp_path):
        cfg, model, params, donor = engine_setup
        d = str(tmp_path / "aborted")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit_all(sessions, cfg.vocab_size, max_tokens=24)
        for _ in range(3):
            eng.tick()
        assert eng.save_snapshot() is not None
        for s in sessions:
            s.pump()
        # An unbounded snapshot.restore fault: every retry aborts, the
        # engine gives up EMPTY — handles fail typed instead of hanging.
        plan = FaultPlan([FaultRule("snapshot.restore", nth=1, times=10**6)])
        eng2 = _mk(model, params, snapshot_dir=d, fault_plan=plan)
        _share_jit(eng2, donor)
        assert eng2.restore_latest(retries=3) is None
        assert eng2.pool.n_seqs() == 0 and eng2.pool.used_pages() == 0
        # A finite fault goes quiet and the retry loop succeeds.
        plan2 = FaultPlan([FaultRule("snapshot.restore", nth=1, times=2)])
        eng3 = _mk(model, params, snapshot_dir=d, fault_plan=plan2)
        _share_jit(eng3, donor)
        assert eng3.restore_latest() is not None
        sessions = [eng3.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        _drive(eng3, handles)
        assert all(h.response.fsm.state.endswith("COMPLETED")
                   for h in handles)

    def test_config_mismatch_refuses_restore(self, engine_setup, tmp_path):
        cfg, model, params, donor = engine_setup
        d = str(tmp_path / "shape")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        eng.connect(0)
        assert eng.save_snapshot() is not None
        other = _mk(model, params, snapshot_dir=d, pool_pages=12)
        _share_jit(other, donor)
        snap, _ = snapshot_mod.load_latest(d)
        with pytest.raises(SnapshotError, match="config mismatch"):
            other.restore(snap)


# ---------------------------------------------------------------------------
# In-process restart (serve_forever(restart=True))
# ---------------------------------------------------------------------------
class TestSelfRestart:
    def test_loop_crash_restores_and_finishes(self, engine_setup,
                                              tmp_path):
        cfg, model, params, donor = engine_setup
        eng = _mk(model, params, snapshot_dir=str(tmp_path / "loop"),
                  snapshot_every=2)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        orig_tick = eng.tick
        calls = {"n": 0}

        def crashing_tick():
            calls["n"] += 1
            if calls["n"] == 5:
                raise RuntimeError("injected loop crash")
            return orig_tick()

        eng.tick = crashing_tick
        t = threading.Thread(target=eng.serve_forever,
                             kwargs={"restart": True}, daemon=True)
        t.start()
        handles = _submit_all(sessions, cfg.vocab_size, max_tokens=12)
        deadline = time.monotonic() + 60
        while not all(h.test() for h in handles):
            assert time.monotonic() < deadline, "restarted engine wedged"
            time.sleep(0.005)
        eng.stop()
        t.join(timeout=10)
        assert not t.is_alive()
        assert eng.dead is None
        assert eng.stats["restarts"] == 1
        assert all(h.response.fsm.state.endswith("COMPLETED")
                   for h in handles)

    def test_restart_budget_bounds_crash_loops(self, engine_setup,
                                               tmp_path):
        cfg, model, params, donor = engine_setup
        eng = _mk(model, params, snapshot_dir=str(tmp_path / "budget"),
                  snapshot_every=2)
        _share_jit(eng, donor)
        eng.connect(0)
        eng.tick()
        assert eng.save_snapshot() is not None
        eng.tick = lambda: (_ for _ in ()).throw(
            RuntimeError("deterministic crash"))
        t = threading.Thread(target=eng.serve_forever,
                             kwargs={"restart": True}, daemon=True)
        t.start()
        t.join(timeout=60)
        assert not t.is_alive(), "crash loop must terminate"
        assert eng.dead is not None         # budget spent -> typed death
        assert eng.stats["restarts"] == 5


# ---------------------------------------------------------------------------
# Session reconnect semantics
# ---------------------------------------------------------------------------
class TestSessionReconnect:
    def test_terminal_redelivery_is_deduped(self, engine_setup, tmp_path):
        cfg, model, params, donor = engine_setup
        d = str(tmp_path / "dedupe")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit_all(sessions, cfg.vocab_size, n=2, max_tokens=3)
        # Complete both WITHOUT pumping: their terminals sit undelivered
        # in the response ring and are captured by the snapshot.
        ticks = 0
        while not all(h.req.done_t for h in handles):
            ticks += 1
            assert ticks < MAX_TICKS
            eng.tick()
        assert eng.save_snapshot() is not None
        # The client then DID receive them before the crash ...
        assert all(h.test() for h in handles)
        n_finalized = [len(s._finalized) for s in sessions]
        # ... so the restore's re-delivery must be dropped client-side.
        eng2 = _mk(model, params, snapshot_dir=d)
        _share_jit(eng2, donor)
        assert eng2.restore_latest() is not None
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        for s in sessions:
            s.pump()
        assert all(not s._completed for s in sessions), \
            "duplicate terminal leaked past the dedupe set"
        assert [len(s._finalized) for s in sessions] == n_finalized

    def test_close_is_idempotent_and_reconnect_reopens(self, engine_setup):
        cfg, model, params, donor = engine_setup
        eng = _mk(model, params)
        _share_jit(eng, donor)
        sess = eng.connect(0)
        sess.close()
        sess.close()                        # idempotent
        assert sess.closed
        again = eng.connect(0)
        assert again is sess and not again.closed
        h = again.submit_i(np.arange(4, dtype=np.int32), max_tokens=2)
        _drive(eng, [h])
        assert h.response.fsm.state.endswith("COMPLETED")

    def test_adopt_is_idempotent_and_closes_donor(self, engine_setup,
                                                  tmp_path):
        cfg, model, params, donor = engine_setup
        d = str(tmp_path / "adopt")
        eng = _mk(model, params, snapshot_dir=d)
        _share_jit(eng, donor)
        old = eng.connect(0)
        h = old.submit_i(np.arange(4, dtype=np.int32) % cfg.vocab_size,
                         max_tokens=24)
        for _ in range(2):
            eng.tick()
        assert eng.save_snapshot() is not None
        old.pump()
        eng2 = _mk(model, params, snapshot_dir=d)
        _share_jit(eng2, donor)
        assert eng2.restore_latest() is not None
        new = eng2.connect(0, resume=old)
        assert old.closed and not new._handles.keys() - {h.req_id}
        assert h._session is new
        old.close()                         # closing the husk: no-op
        eng2.connect(0, resume=new)         # self-adopt: no-op
        assert not new.closed
        _drive(eng2, [h])
        assert h.response.fsm.state.endswith("COMPLETED")


# ---------------------------------------------------------------------------
# Property test: the restored pool is EXACTLY the captured pool
# ---------------------------------------------------------------------------
def _assert_state_equal(a, b, path=""):
    assert type(a) is type(b), f"{path}: {type(a)} != {type(b)}"
    if isinstance(a, dict):
        assert set(a) == set(b), f"{path}: keys {set(a) ^ set(b)}"
        for k in a:
            _assert_state_equal(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), f"{path}: len {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_state_equal(x, y, f"{path}[{i}]")
    elif isinstance(a, np.ndarray):
        assert np.array_equal(a, b), f"{path}: arrays differ"
    else:
        assert a == b, f"{path}: {a!r} != {b!r}"


if st is None:
    def test_hypothesis_roundtrip_property():
        pytest.importorskip("hypothesis")   # records the skip with reason
else:
    class TestSnapshotRoundtripProperties:
        @given(
            n_requests=st.integers(1, 4),
            max_tokens=st.integers(2, 12),
            kill_tick=st.integers(1, 8),
            seed=st.integers(0, 2**16),
        )
        @settings(max_examples=8, deadline=None)
        def test_restore_is_exact(self, engine_setup, tmp_path_factory,
                                  n_requests, max_tokens, kill_tick, seed):
            """snapshot→restore is the identity on the pool: refcounts,
            block tables, the free-page ledger, page bytes, and the
            copy-traffic ledger come back EXACTLY, and the resumed
            engine finishes with the same tokens as the donor run."""
            cfg, model, params, donor = engine_setup
            d = str(tmp_path_factory.mktemp("prop"))
            eng = _mk(model, params, snapshot_dir=d)
            _share_jit(eng, donor)
            sessions = [eng.connect(c) for c in range(2)]
            handles = _submit_all(sessions, cfg.vocab_size,
                                  n=n_requests, max_tokens=max_tokens,
                                  seed=seed)
            for _ in range(kill_tick):
                if all(h.test() for h in handles):
                    break
                eng.tick()
            snap = eng.snapshot()
            extra = (eng.prefix_cache.resident_pages()
                     if eng.prefix_cache is not None else ())
            want = eng.pool.snapshot_state(extra_pages=extra)
            eng2 = _mk(model, params, snapshot_dir=d)
            _share_jit(eng2, donor)
            eng2.restore(snap)
            extra2 = (eng2.prefix_cache.resident_pages()
                      if eng2.prefix_cache is not None else ())
            got = eng2.pool.snapshot_state(extra_pages=extra2)
            _assert_state_equal(want, got, "pool")
            assert eng2.pool.kv_copy_bytes == (eng2.pool.cow_copy_bytes
                                               + eng2.pool.swap_in_bytes
                                               + eng2.pool.swap_out_bytes)
            # Finish both lives; streams must agree byte-for-byte.
            for s in sessions:
                s.pump()
            for c, s in enumerate(sessions):
                eng2.connect(c, resume=s)
            _drive(eng2, handles)
            ref, _ = _run_reference(model, params, donor, cfg.vocab_size,
                                    n=n_requests, max_tokens=max_tokens,
                                    seed=seed)
            assert _tokens_of(handles) == ref
            _pool_clean(eng2)
