"""STATE (NBW) channels + broadcast composition (paper §7 future work
and Kim'07's pub/sub composition claim)."""
import threading

from repro.core import nbb, states
from repro.core.channels import ChannelType, Domain
from repro.core.host_queue import BroadcastChannel


def test_state_channel_freshest_wins():
    dom = Domain()
    a, b = dom.create_endpoint(0, 1), dom.create_endpoint(1, 1)
    ch = dom.connect(ChannelType.STATE, a, b)
    status, v = ch.recv()
    assert status == nbb.BUFFER_EMPTY and v is None
    for i in range(10):
        assert ch.send(i) == nbb.OK        # writer never blocks
    status, v = ch.recv()
    assert status == nbb.OK and v == 9     # newest value, not FIFO head
    status, v = ch.recv()
    assert status == nbb.OK and v == 9     # state re-read is legal


def test_state_channel_never_fills():
    dom = Domain(queue_capacity=2)
    a, b = dom.create_endpoint(0, 2), dom.create_endpoint(1, 2)
    ch = dom.connect(ChannelType.STATE, a, b)
    for i in range(1000):                  # >> any capacity
        assert ch.send(i) == nbb.OK
    assert ch.recv() == (nbb.OK, 999)


def test_state_channel_threaded_monotone_reads():
    """Readers may skip values but never see them go backwards."""
    dom = Domain()
    a, b = dom.create_endpoint(0, 3), dom.create_endpoint(1, 3)
    ch = dom.connect(ChannelType.STATE, a, b, nbw_depth=8)
    n = 20_000
    errors = []

    def writer():
        for i in range(1, n + 1):
            ch.send(i)

    def reader():
        last = 0
        while last < n:
            status, v = ch.recv()
            if status == nbb.OK and v is not None:
                if v < last:
                    errors.append((last, v))
                    return
                last = v

    tw, tr = threading.Thread(target=writer), threading.Thread(target=reader)
    tr.start(); tw.start()
    tw.join(); tr.join(timeout=30)
    assert not errors, errors[0]


def test_state_channel_recv_i_handle():
    """STATE receives through the non-blocking handle API: a recv_i on an
    unpublished cell stays PENDING, polls to completion once the writer
    commits, and re-polling a fresh handle re-reads state legally."""
    dom = Domain()
    a, b = dom.create_endpoint(0, 7), dom.create_endpoint(1, 7)
    ch = dom.connect(ChannelType.STATE, a, b)
    h = ch.recv_i()
    assert not h.done and h.last_status == nbb.BUFFER_EMPTY
    assert h.test() is False               # still nothing published
    for i in range(5):
        assert ch.send(i) == nbb.OK        # writer never blocks
    assert h.test() is True                # poll completes on fresh value
    assert h.completed and h.result == 4   # freshest, not FIFO head
    assert h.test() is True                # terminal handles stay terminal
    h2 = ch.recv_i()                       # state re-read via a new handle
    assert h2.completed and h2.result == 4


def test_state_channel_recv_i_rides_out_write_collision():
    """A recv_i issued while the writer is mid-publish observes the
    transient Table-1 status and completes via wait() once the write
    commits (the NBW Timeliness property through the handle API)."""
    dom = Domain()
    ch = dom.connect(ChannelType.STATE, dom.create_endpoint(0, 8),
                     dom.create_endpoint(1, 8))
    cell = ch.queue
    cell.write("v0")
    v = cell._version
    cell._version = v + 1                  # writer stuck mid-publish
    h = ch.recv_i()
    assert not h.done
    assert h.last_status == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING

    def commit():
        cell._bufs[((v // 2) + 1) % cell._depth] = "v1"
        cell._version = v + 2

    timer = threading.Timer(0.02, commit)
    timer.start()
    assert h.wait(timeout_s=5) is True
    timer.join()
    assert h.result == "v1"


def test_state_channel_recv_i_cancel():
    """cancel() on a pending STATE recv wins the CAS; a later publish no
    longer completes the handle (exactly one terminal state)."""
    dom = Domain()
    ch = dom.connect(ChannelType.STATE, dom.create_endpoint(0, 9),
                     dom.create_endpoint(1, 9))
    h = ch.recv_i()
    assert h.cancel() is True
    assert h.cancel() is False             # second cancel loses
    ch.send("late")
    assert h.test() is False and h.state == states.OP_CANCELLED
    assert h.result is None


def test_broadcast_every_consumer_gets_every_item():
    bc = BroadcastChannel(3, capacity=8)
    sent = list(range(5))
    for x in sent:
        bc.publish(x)
    for c in range(3):
        got = []
        ring = bc.consumer(c)
        while True:
            status, item = ring.read_item()
            if status != nbb.OK:
                break
            got.append(item)
        assert got == sent, (c, got)


def test_broadcast_slow_consumer_only_stalls_itself():
    bc = BroadcastChannel(2, capacity=4)
    for x in range(4):
        statuses = bc.insert_item(x)
        assert statuses == [nbb.OK, nbb.OK]
    # consumer 0 drains, consumer 1 stalls
    for _ in range(4):
        assert bc.consumer(0).read_item()[0] == nbb.OK
    statuses = bc.insert_item(99)
    assert statuses[0] == nbb.OK           # fast ring accepts
    assert statuses[1] != nbb.OK           # stalled ring reports FULL
