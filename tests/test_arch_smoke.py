"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU; asserts shapes and
finiteness.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models.config import ShapeConfig
from repro.models.inputs import concrete, train_batch_specs
from repro.models.model import build_model

SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=16, global_batch=2)

ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, built):
    cfg, model, params = built(arch)
    batch = concrete(train_batch_specs(cfg, SMOKE_SHAPE), vocab=cfg.vocab_size)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, built):
    cfg, model, params = built(arch)
    batch = concrete(train_batch_specs(cfg, SMOKE_SHAPE), vocab=cfg.vocab_size)

    def lossfn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(lossfn))(params)
    assert bool(jnp.isfinite(loss))
    assert _finite(grads), f"{arch}: non-finite grads"
    # At least the embedding grads must be non-zero.
    g = grads["embed"]["table"]
    assert float(jnp.abs(g).max()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_tree_matches(arch, built):
    cfg, model, params = built(arch)
    axes = model.param_axes()
    pt, at = jax.tree.structure(params), jax.tree.structure(
        axes, is_leaf=lambda x: not isinstance(x, dict))
    flat_p = jax.tree.leaves(params)
    from repro.parallel.sharding import Axes
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, Axes))
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert isinstance(a, Axes)
        assert len(a.names) == p.ndim, f"{arch}: {a} vs shape {p.shape}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    B, T = 2, 8
    rng = jax.random.PRNGKey(1)
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size, jnp.int32)
    extras = None
    if cfg.family == "vlm":
        extras = jax.random.normal(rng, (B, cfg.num_image_tokens, cfg.d_model),
                                   jnp.float32).astype(cfg.compute_dtype)
    if cfg.encoder is not None:
        extras = jax.random.normal(rng, (B, cfg.encoder.num_frames, cfg.d_model),
                                   jnp.float32).astype(cfg.compute_dtype)
    max_len = 16
    tok, caches = jax.jit(model.prefill, static_argnames="max_len")(
        params, tokens, max_len=max_len, extras=extras)
    assert tok.shape == (B,)
    assert _finite(caches), f"{arch}: non-finite cache after prefill"
    step = jax.jit(model.decode_step)
    for i in range(3):
        tok2, caches = step(params, caches, tok[:, None], jnp.int32(T + i))
        assert tok2.shape == (B,)
        assert bool(jnp.all((tok2 >= 0) & (tok2 < cfg.vocab_size)))
        tok = tok2


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-1.6b", "zamba2-2.7b"])
def test_decode_matches_forward(arch, built):
    """Greedy decode from a filled cache must agree with teacher-forced
    forward on the same prefix (incremental == batch computation)."""
    cfg, model, params = built(arch)
    B, T = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                cfg.vocab_size, jnp.int32)
    # Full forward argmax at each position.
    from repro.models.layers import unembed_matrix
    from repro.models.losses import full_logits
    hidden, _, _ = model.forward(params, tokens)
    w = unembed_matrix(params["embed"], cfg).astype(cfg.compute_dtype)
    ref = jnp.argmax(full_logits(hidden, w), axis=-1)  # [B, T]

    # Prefill on the first half, decode the rest teacher-forced.
    half = T // 2
    tok, caches = model.prefill(params, tokens[:, :half], max_len=T + 4)
    assert int(tok[0]) == int(ref[0, half - 1])
    for i in range(half, T):
        tok, caches = model.decode_step(params, caches, tokens[:, i:i + 1],
                                        jnp.int32(i))
        assert int(tok[0]) == int(ref[0, i]), f"{arch}: mismatch at pos {i}"
