"""Loss-layer invariants: both xent chunk layouts agree with each other
and with the naive full-logits oracle; masking semantics hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.models.losses import chunked_softmax_xent, full_logits

jax.config.update("jax_platform_name", "cpu")


def naive_xent(hidden, w_out, labels, weights=None):
    logits = jnp.einsum("btd,dv->btv", hidden.astype(jnp.float32),
                        w_out.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=2)[..., 0]
    w = (jnp.ones(labels.shape, jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    return jnp.sum((lse - ll) * w) / jnp.maximum(jnp.sum(w), 1.0)


def _data(seed, B=2, T=32, D=16, V=64):
    k = jax.random.PRNGKey(seed)
    hidden = jax.random.normal(k, (B, T, D), jnp.float32)
    w_out = jax.random.normal(jax.random.fold_in(k, 1), (D, V), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(k, 2), (B, T), 0, V)
    return hidden, w_out, labels


@pytest.mark.parametrize("layout", ["flat", "batched"])
@pytest.mark.parametrize("chunk", [8, 16, 2048])
def test_layouts_match_naive(layout, chunk):
    hidden, w_out, labels = _data(0)
    got = chunked_softmax_xent(hidden, w_out, labels, token_chunk=chunk,
                               layout=layout)
    want = naive_xent(hidden, w_out, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_layouts_match_each_other_with_weights():
    hidden, w_out, labels = _data(1)
    weights = jnp.ones(labels.shape, jnp.float32).at[:, -1].set(0.0)
    a = chunked_softmax_xent(hidden, w_out, labels, weights=weights,
                             layout="flat")
    b = chunked_softmax_xent(hidden, w_out, labels, weights=weights,
                             layout="batched")
    want = naive_xent(hidden, w_out, labels, weights)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
    np.testing.assert_allclose(float(a), float(want), rtol=1e-5)


def test_masked_position_has_no_gradient():
    hidden, w_out, labels = _data(2)
    weights = jnp.ones(labels.shape, jnp.float32).at[:, -1].set(0.0)

    def f(h):
        return chunked_softmax_xent(h, w_out, labels, weights=weights)

    g = jax.grad(f)(hidden)
    np.testing.assert_array_equal(np.asarray(g[:, -1]), 0.0)
    assert float(jnp.abs(g[:, :-1]).max()) > 0


if st is None:
    def test_layout_equivalence_property():
        pytest.importorskip("hypothesis")  # records the skip with reason
else:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_layout_equivalence_property(seed):
        hidden, w_out, labels = _data(seed, B=1, T=16, D=8, V=32)
        a = chunked_softmax_xent(hidden, w_out, labels, token_chunk=4,
                                 layout="flat")
        b = chunked_softmax_xent(hidden, w_out, labels, token_chunk=4,
                                 layout="batched")
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


def test_full_logits_shape():
    hidden, w_out, _ = _data(3)
    out = full_logits(hidden[:, -1:], w_out)
    assert out.shape == (2, 1, 64)
