"""Tests for the core lock-free library: NBB, NBW, bitset, FSMs, queues.

Validates the paper's three design properties (Section 3):
  Safety       — a successful read never returns a corrupted value,
  Timeliness   — failed ops return immediately with a status (bounded retry),
  Non-blocking — the writer is never blocked by readers and vice versa.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency (requirements-dev.txt); property tests
    from hypothesis import given, settings, strategies as st  # skip without it
except ImportError:
    given = settings = st = None

from repro.core import bitset, nbb, nbw, states
from repro.core.channels import ChannelType, Domain
from repro.core.host_queue import LockedQueue, MpscQueue
from repro.core.nbb import HostNBB, SimNBB
from repro.core.refcount import RefCountArray


# ---------------------------------------------------------------------------
# HostNBB — single-threaded semantics
# ---------------------------------------------------------------------------
class TestHostNBB:
    def test_fifo_order(self):
        q = HostNBB(8)
        for i in range(8):
            assert q.insert_item(i) == nbb.OK
        assert q.insert_item(99) == nbb.BUFFER_FULL
        for i in range(8):
            status, item = q.read_item()
            assert status == nbb.OK and item == i
        status, item = q.read_item()
        assert status == nbb.BUFFER_EMPTY and item is None

    def test_wraparound(self):
        q = HostNBB(3)
        for round_ in range(10):
            for i in range(3):
                assert q.insert_item((round_, i)) == nbb.OK
            for i in range(3):
                status, item = q.read_item()
                assert status == nbb.OK and item == (round_, i)

    def test_len(self):
        q = HostNBB(4)
        assert len(q) == 0
        q.insert_item(1)
        q.insert_item(2)
        assert len(q) == 2
        q.read_item()
        assert len(q) == 1

    def test_capacity_one(self):
        q = HostNBB(1)
        assert q.insert_item("x") == nbb.OK
        assert q.insert_item("y") == nbb.BUFFER_FULL
        assert q.read_item() == (nbb.OK, "x")


# ---------------------------------------------------------------------------
# HostNBB — real two-thread stress (the paper's stress-test design, §4:
# transaction IDs 1..1000 verified in sequence at the receiver).
# ---------------------------------------------------------------------------
class TestHostNBBThreaded:
    @pytest.mark.parametrize("capacity", [1, 2, 16])
    def test_spsc_transaction_ids_in_order(self, capacity):
        q = HostNBB(capacity)
        n = 1000
        received = []
        errs = []

        def producer():
            for txn in range(1, n + 1):
                q.put(txn)

        def consumer():
            for _ in range(n):
                item = q.get()
                received.append(item)

        t1 = threading.Thread(target=producer)
        t2 = threading.Thread(target=consumer)
        t1.start(); t2.start()
        t1.join(timeout=30); t2.join(timeout=30)
        assert not errs
        assert received == list(range(1, n + 1)), "FIFO order violated"

    def test_multi_payload_types(self):
        """message/packet/scalar payloads all travel uncorrupted."""
        q = HostNBB(8)
        payloads = [b"m" * 24, ("packet", bytes(24)), 0xDEADBEEF]
        done = []

        def producer():
            for p in payloads * 100:
                q.put(p)

        def consumer():
            for _ in range(len(payloads) * 100):
                done.append(q.get())

        t1, t2 = threading.Thread(target=producer), threading.Thread(target=consumer)
        t1.start(); t2.start(); t1.join(30); t2.join(30)
        assert done == payloads * 100


# ---------------------------------------------------------------------------
# Functional JAX NBB
# ---------------------------------------------------------------------------
class TestJaxNBB:
    def test_fifo_roundtrip_jit(self):
        @jax.jit
        def run():
            s = nbb.init(4, jnp.zeros((3,), jnp.float32))
            outs, statuses = [], []
            for i in range(4):
                s, st_ = nbb.insert_item(s, jnp.full((3,), float(i)))
                statuses.append(st_)
            s, st_full = nbb.insert_item(s, jnp.full((3,), 9.0))
            for _ in range(4):
                s, item, st_ = nbb.read_item(s)
                outs.append(item)
            _, _, st_empty = nbb.read_item(s)
            return outs, statuses, st_full, st_empty

        outs, statuses, st_full, st_empty = run()
        for i, o in enumerate(outs):
            np.testing.assert_allclose(o, np.full(3, i))
        assert all(int(s) == nbb.OK for s in statuses)
        assert int(st_full) == nbb.BUFFER_FULL
        assert int(st_empty) == nbb.BUFFER_EMPTY

    def test_full_insert_is_noop(self):
        s = nbb.init(1, jnp.zeros((), jnp.int32))
        s, _ = nbb.insert_item(s, jnp.int32(7))
        s2, status = nbb.insert_item(s, jnp.int32(8))
        assert int(status) == nbb.BUFFER_FULL
        _, item, _ = nbb.read_item(s2)
        assert int(item) == 7, "full insert must not overwrite"

    def test_usable_as_scan_carry(self):
        def body(s, x):
            s, _ = nbb.insert_item(s, x)
            s, item, _ = nbb.read_item(s)
            return s, item

        s0 = nbb.init(2, jnp.zeros((), jnp.float32))
        xs = jnp.arange(10, dtype=jnp.float32)
        _, ys = jax.lax.scan(body, s0, xs)
        np.testing.assert_allclose(ys, xs)


# ---------------------------------------------------------------------------
# NBW
# ---------------------------------------------------------------------------
class TestNBW:
    def test_host_roundtrip(self):
        w = nbw.HostNBW(depth=2)
        for v in range(20):
            w.write(v)
            assert w.read() == v
        assert w.version == 20

    def test_reader_sees_latest_not_order(self):
        w = nbw.HostNBW(depth=4)
        w.write("a"); w.write("b"); w.write("c")
        assert w.read() == "c"  # state messages: latest wins

    def test_threaded_no_corruption(self):
        """Readers under a writer storm never observe torn values.

        Values are (i, i*i) pairs; a torn read would mismatch the pair."""
        w = nbw.HostNBW(depth=2)
        w.write((0, 0))
        stop = threading.Event()
        bad = []

        def writer():
            i = 0
            while not stop.is_set():
                w.write((i, i * i))
                i += 1

        def reader():
            for _ in range(20000):
                i, sq = w.read()
                if sq != i * i:
                    bad.append((i, sq))

        wt = threading.Thread(target=writer)
        rts = [threading.Thread(target=reader) for _ in range(2)]
        wt.start(); [t.start() for t in rts]
        [t.join(60) for t in rts]
        stop.set(); wt.join(10)
        assert not bad, f"torn NBW reads: {bad[:3]}"

    def test_jax_functional(self):
        s = nbw.init(2, jnp.zeros((4,), jnp.float32))
        for v in range(5):
            s = nbw.write(s, jnp.full((4,), float(v)))
            value, version = nbw.read(s)
            np.testing.assert_allclose(value, np.full(4, v))
            assert int(version) == v + 1


# ---------------------------------------------------------------------------
# Bitset
# ---------------------------------------------------------------------------
class TestBitset:
    def test_host_claim_release(self):
        b = bitset.HostBitset(4)
        got = [b.try_claim(f"o{i}") for i in range(4)]
        assert sorted(got) == [0, 1, 2, 3]
        assert b.try_claim("x") is None
        b.release(2)
        assert b.try_claim("y") == 2

    def test_host_threaded_unique_claims(self):
        """N threads racing for slots never double-claim (CAS property)."""
        b = bitset.HostBitset(64)
        claims = [[] for _ in range(8)]

        def worker(tid):
            while True:
                s = b.try_claim(owner=(tid, len(claims[tid])))
                if s is None:
                    return
                claims[tid].append(s)

        ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        [t.start() for t in ts]; [t.join(30) for t in ts]
        all_claims = [s for c in claims for s in c]
        assert sorted(all_claims) == list(range(64)), "double-claimed slots"

    def test_jax_claim_release_full(self):
        bits = bitset.init(5)
        slots = []
        for _ in range(5):
            bits, s = bitset.claim_first_free(bits, 5)
            slots.append(int(s))
        assert slots == [0, 1, 2, 3, 4]
        bits, s = bitset.claim_first_free(bits, 5)
        assert int(s) == -1  # full: non-blocking failure
        bits = bitset.release(bits, jnp.int32(3))
        assert not bool(bitset.is_claimed(bits, jnp.int32(3)))
        bits, s = bitset.claim_first_free(bits, 5)
        assert int(s) == 3
        assert int(bitset.count(bits)) == 5


# ---------------------------------------------------------------------------
# RefCountArray — the bitset's refcounted generalization (shared KV pages)
# ---------------------------------------------------------------------------
class TestRefCount:
    def test_claim_share_release_lifecycle(self):
        r = RefCountArray(4)
        assert r.try_claim() == 0
        assert r.refcount(0) == 1
        assert r.incref(0) == 2          # fetch-add share
        assert r.decref(0) == 1          # fetch-sub release
        assert r.is_claimed(0)
        assert r.decref(0) == 0          # last ref: back to the free set
        assert not r.is_claimed(0)
        assert r.try_claim() == 0        # immediately claimable again

    def test_free_slot_refuses_share_and_release(self):
        """incref requires a holder; decref without a reference is a bug,
        not a silent no-op — both raise instead of corrupting the count."""
        r = RefCountArray(2)
        with pytest.raises(KeyError):
            r.incref(1)
        with pytest.raises(KeyError):
            r.decref(1)

    def test_claim_specific_only_from_zero(self):
        r = RefCountArray(2)
        assert r.claim_specific(1) is True
        assert r.claim_specific(1) is False   # held: CAS fails
        r.incref(1)
        r.decref(1)
        assert r.claim_specific(1) is False   # still held (count 1)
        r.decref(1)
        assert r.claim_specific(1) is True    # free again

    def test_full_pool_returns_none(self):
        r = RefCountArray(3)
        assert sorted(r.try_claim() for _ in range(3)) == [0, 1, 2]
        assert r.try_claim() is None          # non-blocking failure
        r.release(1)                          # HostBitset-compatible alias
        assert r.try_claim() == 1

    def test_counts(self):
        r = RefCountArray(4)
        r.try_claim()
        r.try_claim()
        r.incref(0)
        assert r.count() == 2                 # held slots, counted once
        assert r.shared_count() == 1          # only slot 0 is shared
        assert r.refcount(0) == 2 and r.refcount(1) == 1

    def test_claim_from_zero_single_winner_threaded(self):
        """Claim-from-zero is the one transition needing mutual exclusion
        between claimers: N threads racing for the same free slot yield
        exactly one winner, and the slot returns to the free set exactly
        once per release (no double-claim ever observed across rounds)."""
        r = RefCountArray(1)
        for _round in range(50):
            wins = []
            barrier = threading.Barrier(4)

            def claimer():
                barrier.wait()
                if r.claim_specific(0):
                    wins.append(1)

            ts = [threading.Thread(target=claimer) for _ in range(4)]
            [t.start() for t in ts]
            [t.join(10) for t in ts]
            assert len(wins) == 1, f"{len(wins)} CAS winners"
            assert r.refcount(0) == 1
            assert r.decref(0) == 0

    def test_shared_slot_incref_decref_storm(self):
        """incref/decref from many threads on one shared slot never lose
        an update (the fetch-add/fetch-sub property): with the base
        reference pinned, the count comes back to exactly 1 after the
        storm, and the slot never transiently frees (claim_specific by a
        rival must fail throughout)."""
        r = RefCountArray(1)
        assert r.try_claim() == 0            # base ref pinned by the test
        stolen = []
        stop = threading.Event()

        def churner():
            for _ in range(5000):
                r.incref(0)
                r.decref(0)

        def thief():
            while not stop.is_set():
                if r.claim_specific(0):      # only possible at count 0
                    stolen.append(1)
                    r.decref(0)

        ts = [threading.Thread(target=churner) for _ in range(4)]
        tt = threading.Thread(target=thief)
        [t.start() for t in ts]
        tt.start()
        [t.join(60) for t in ts]
        stop.set()
        tt.join(10)
        assert not stolen, "slot freed while referenced"
        assert r.refcount(0) == 1, "lost incref/decref update"


# ---------------------------------------------------------------------------
# State machines (paper Figures 3 & 4)
# ---------------------------------------------------------------------------
class TestStateMachines:
    def test_request_lifecycle(self):
        c = states.request_cell()
        c.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        c.transition(states.REQUEST_VALID, states.REQUEST_RECEIVED)
        c.transition(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED)
        c.transition(states.REQUEST_COMPLETED, states.REQUEST_FREE)
        assert c.state == states.REQUEST_FREE

    def test_cancel_path(self):
        c = states.request_cell()
        c.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        c.transition(states.REQUEST_VALID, states.REQUEST_CANCELLED)
        c.transition(states.REQUEST_CANCELLED, states.REQUEST_FREE)

    def test_illegal_transition_raises(self):
        c = states.request_cell()
        with pytest.raises(states.IllegalTransition):
            c.cas(states.REQUEST_FREE, states.REQUEST_COMPLETED)

    def test_cas_loser_detected(self):
        c = states.request_cell()
        assert c.cas(states.REQUEST_FREE, states.REQUEST_VALID) is True
        assert c.cas(states.REQUEST_FREE, states.REQUEST_VALID) is False

    def test_racing_threads_single_winner(self):
        """Only one of N racing threads wins each FREE->VALID claim."""
        c = states.request_cell()
        wins = []

        def claimer(tid):
            if c.cas(states.REQUEST_FREE, states.REQUEST_VALID):
                wins.append(tid)

        for _round in range(50):
            ts = [threading.Thread(target=claimer, args=(i,)) for i in range(4)]
            [t.start() for t in ts]; [t.join(10) for t in ts]
            assert len(wins) == 1, f"multiple CAS winners: {wins}"
            c.transition(states.REQUEST_VALID, states.REQUEST_COMPLETED)
            c.transition(states.REQUEST_COMPLETED, states.REQUEST_FREE)
            wins.clear()

    def test_buffer_lifecycle(self):
        c = states.buffer_cell()
        for a, b in [(states.BUFFER_FREE, states.BUFFER_RESERVED),
                     (states.BUFFER_RESERVED, states.BUFFER_ALLOCATED),
                     (states.BUFFER_ALLOCATED, states.BUFFER_RECEIVED),
                     (states.BUFFER_RECEIVED, states.BUFFER_FREE)]:
            c.transition(a, b)
        assert c.state == states.BUFFER_FREE

    def test_buffer_preempt_resume_cycle(self):
        """Figure-4 extension (DESIGN.md §12): ALLOCATED -> PREEMPTED
        parks a swapped-out sequence; PREEMPTED -> ALLOCATED resumes
        it; PREEMPTED -> FREE is cancel-while-parked."""
        c = states.buffer_cell()
        c.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        c.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        c.transition(states.BUFFER_ALLOCATED, states.BUFFER_PREEMPTED)
        c.transition(states.BUFFER_PREEMPTED, states.BUFFER_ALLOCATED)
        c.transition(states.BUFFER_ALLOCATED, states.BUFFER_RECEIVED)
        c.transition(states.BUFFER_RECEIVED, states.BUFFER_FREE)
        assert c.state == states.BUFFER_FREE
        # cancel-while-parked path
        c.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        c.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        c.transition(states.BUFFER_ALLOCATED, states.BUFFER_PREEMPTED)
        c.transition(states.BUFFER_PREEMPTED, states.BUFFER_FREE)
        assert c.state == states.BUFFER_FREE

    def test_buffer_preempt_illegal_edges(self):
        """Only an ALLOCATED (fully prefilled) sequence can park, and a
        parked one cannot retire without resuming first."""
        c = states.buffer_cell()
        c.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        with pytest.raises(states.IllegalTransition):
            c.cas(states.BUFFER_RESERVED, states.BUFFER_PREEMPTED)
        c.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        c.transition(states.BUFFER_ALLOCATED, states.BUFFER_PREEMPTED)
        with pytest.raises(states.IllegalTransition):
            c.cas(states.BUFFER_PREEMPTED, states.BUFFER_RECEIVED)
        # racing resume vs cancel-while-parked: exactly one CAS wins
        assert c.cas(states.BUFFER_PREEMPTED, states.BUFFER_ALLOCATED)
        assert not c.cas(states.BUFFER_PREEMPTED, states.BUFFER_FREE)

    def test_journal_compaction_preserves_state(self):
        c = states.request_cell()
        for _ in range(100):  # force several compactions
            c.transition(states.REQUEST_FREE, states.REQUEST_VALID)
            c.transition(states.REQUEST_VALID, states.REQUEST_COMPLETED)
            c.transition(states.REQUEST_COMPLETED, states.REQUEST_FREE)
        assert c.state == states.REQUEST_FREE


# ---------------------------------------------------------------------------
# MPSC composition + MCAPI channel API
# ---------------------------------------------------------------------------
class TestQueuesAndChannels:
    def test_mpsc_fan_in(self):
        q = MpscQueue(nproducers=4, capacity_per_producer=16)
        n_each = 500
        def producer(pid):
            for i in range(n_each):
                q.producer(pid).put((pid, i))
        got = []
        def consumer():
            for _ in range(4 * n_each):
                got.append(q.get())
        ts = [threading.Thread(target=producer, args=(p,)) for p in range(4)]
        tc = threading.Thread(target=consumer)
        [t.start() for t in ts]; tc.start()
        [t.join(30) for t in ts]; tc.join(30)
        assert len(got) == 4 * n_each
        # Per-producer FIFO order must hold even through the fan-in.
        for pid in range(4):
            seq = [i for (p, i) in got if p == pid]
            assert seq == list(range(n_each))

    def test_locked_queue_baseline_semantics(self):
        q = LockedQueue(2)
        assert q.insert_item(1) == nbb.OK
        assert q.insert_item(2) == nbb.OK
        assert q.insert_item(3) == nbb.BUFFER_FULL
        assert q.read_item() == (nbb.OK, 1)

    @pytest.mark.parametrize("lock_free", [True, False])
    def test_mcapi_channel_roundtrip(self, lock_free):
        dom = Domain(lock_free=lock_free, queue_capacity=8)
        tx = dom.create_endpoint(node=1, port=0)
        for ctype, payload in [
            (ChannelType.MESSAGE, b"hello" * 5),
            (ChannelType.PACKET, bytes(24)),
            (ChannelType.SCALAR, -12345678901),
        ]:
            ch = dom.connect(ctype, tx, dom.create_endpoint(2, hash(ctype.value) % 1000 + 1))
            ch.send_blocking(payload)
            assert ch.recv_blocking() == payload

    def test_scalar_widths(self):
        dom = Domain()
        ch = dom.connect(ChannelType.SCALAR, dom.create_endpoint(0, 1),
                         dom.create_endpoint(0, 2))
        for v in [0, 255, 2 ** 15 - 1, -2 ** 31, 2 ** 63 - 1]:
            ch.send_blocking(v)
            assert ch.recv_blocking() == v


# ---------------------------------------------------------------------------
# Property tests (hypothesis): interleaving simulator proves Safety under
# ANY schedule; functional NBB matches a bounded-FIFO reference model.
# Defined only when hypothesis is installed; otherwise one skip records it.
# ---------------------------------------------------------------------------
if st is None:
    def test_hypothesis_property_tests():
        pytest.importorskip("hypothesis")   # records the skip with reason
else:
    class TestNBBInterleavings:
        @given(
            capacity=st.integers(1, 4),
            schedule=st.lists(st.booleans(), min_size=1, max_size=60),
        )
        @settings(max_examples=300, deadline=None)
        def test_no_torn_reads_any_interleaving(self, capacity, schedule):
            """Under any producer/consumer interleaving of the micro-ops, a
            committed read never observes a torn slot, and FIFO order holds."""
            sim = SimNBB(capacity)
            p_state, c_state = "idle", "idle"
            next_val, expect = 1, 1
            for is_producer in schedule:
                if is_producer:
                    if p_state == "idle":
                        if sim.try_begin_insert() == nbb.OK:
                            sim.write_half(next_val)  # torn intermediate
                            p_state = "mid"
                    else:
                        sim.write_commit(next_val)
                        next_val += 1
                        p_state = "idle"
                else:
                    if c_state == "idle":
                        if sim.try_begin_read() == nbb.OK:
                            c_state = "mid"
                    else:
                        value, torn = sim.read_commit()
                        assert torn == 0, "SAFETY VIOLATION: torn read"
                        assert value == expect, "FIFO order violated"
                        expect += 1
                        c_state = "idle"

        @given(capacity=st.integers(1, 4))
        @settings(max_examples=50, deadline=None)
        def test_status_codes_match_table1(self, capacity):
            sim = SimNBB(capacity)
            # Fill the ring completely.
            for v in range(capacity):
                assert sim.try_begin_insert() == nbb.OK
                sim.write_commit(v)
            assert sim.try_begin_insert() == nbb.BUFFER_FULL
            # Start (but don't finish) a read: producer must see the
            # "consumer reading" variant -> spin, don't yield.
            assert sim.try_begin_read() == nbb.OK
            assert (sim.try_begin_insert()
                    == nbb.BUFFER_FULL_BUT_CONSUMER_READING)
            sim.read_commit()
            # Drain the rest.
            for _ in range(capacity - 1):
                assert sim.try_begin_read() == nbb.OK
                sim.read_commit()
            assert sim.try_begin_read() == nbb.BUFFER_EMPTY
            # Start (but don't finish) an insert: consumer sees the
            # "producer inserting" variant.
            assert sim.try_begin_insert() == nbb.OK
            sim.write_half(123)
            assert (sim.try_begin_read()
                    == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING)

    class TestJaxNBBProperties:
        @given(
            capacity=st.integers(1, 5),
            ops=st.lists(st.booleans(), min_size=1, max_size=40),
        )
        @settings(max_examples=100, deadline=None)
        def test_matches_reference_fifo(self, capacity, ops):
            """The functional NBB behaves exactly like a bounded FIFO."""
            s = nbb.init(capacity, jnp.zeros((), jnp.int32))
            model: list = []
            next_val = 0
            for is_insert in ops:
                if is_insert:
                    s, status = nbb.insert_item(s, jnp.int32(next_val))
                    if len(model) < capacity:
                        assert int(status) == nbb.OK
                        model.append(next_val)
                        next_val += 1
                    else:
                        assert int(status) == nbb.BUFFER_FULL
                else:
                    s, item, status = nbb.read_item(s)
                    if model:
                        assert int(status) == nbb.OK
                        assert int(item) == model.pop(0)
                    else:
                        assert int(status) == nbb.BUFFER_EMPTY
                assert int(nbb.size(s)) == len(model)

    class TestBitsetProperties:
        @given(n=st.integers(1, 100))
        @settings(max_examples=30, deadline=None)
        def test_jax_count_matches(self, n):
            bits = bitset.init(n)
            k = min(n, 7)
            for _ in range(k):
                bits, _ = bitset.claim_first_free(bits, n)
            assert int(bitset.count(bits)) == k

        @given(
            nslots=st.integers(4, 48),
            n_threads=st.integers(2, 5),
            ops=st.integers(5, 40),
            starts=st.lists(st.integers(0, 47), min_size=5, max_size=5),
        )
        @settings(max_examples=25, deadline=None)
        def test_host_bitset_claim_release_race_never_double_allocates(
                self, nslots, n_threads, ops, starts):
            """The page-allocator Safety property under REAL thread races
            (DESIGN.md §10 relies on it: a double-allocated page would
            hand one KV page to two sequences).  Each thread hammers
            claim/release from a hypothesis-chosen probe start; at every
            claim it checks the slot was not already held by anyone, and
            at the barrier all held sets must be disjoint and the free
            count exact."""
            b = bitset.HostBitset(nslots)
            holders = [set() for _ in range(n_threads)]
            violations: list = []
            barrier = threading.Barrier(n_threads)

            def worker(tid):
                mine = holders[tid]
                barrier.wait()
                for i in range(ops):
                    slot = b.try_claim(owner=object(),
                                       start=starts[tid % len(starts)]
                                       % nslots)
                    if slot is not None:
                        if slot in mine:
                            violations.append(("self-dup", tid, slot))
                        mine.add(slot)
                    # release roughly half of what we hold, keep churning
                    if mine and i % 2:
                        b.release(mine.pop())

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not violations, violations
            seen: set = set()
            for mine in holders:
                assert not (mine & seen), "double-allocated page"
                seen |= mine
            assert b.count() == len(seen)
            for s in seen:          # full cleanup releases every claim
                b.release(s)
            assert b.count() == 0

    class TestRefCountProperties:
        @given(
            nslots=st.integers(2, 32),
            n_threads=st.integers(2, 5),
            ops=st.integers(5, 60),
            starts=st.lists(st.integers(0, 47), min_size=5, max_size=5),
        )
        @settings(max_examples=25, deadline=None)
        def test_share_release_claim_race_counts_exact(
                self, nslots, n_threads, ops, starts):
            """The shared-page allocator Safety property under REAL
            thread races (DESIGN.md §11 relies on it: a count drift
            would either free a KV page some sequence still attends or
            leak it forever).  Each thread hammers claim-from-zero,
            share (incref of slots it holds) and release from a
            hypothesis-chosen probe start; after the join every slot's
            count must equal the references the threads still hold —
            exactly — and draining those returns every slot to the free
            set exactly once (each becomes claimable again, count 0)."""
            r = RefCountArray(nslots)
            held = [{} for _ in range(n_threads)]   # tid -> {slot: refs}
            violations: list = []
            barrier = threading.Barrier(n_threads)

            def worker(tid):
                mine = held[tid]
                barrier.wait()
                for i in range(ops):
                    if i % 3 == 0 and mine:          # share what we hold
                        s = next(iter(mine))
                        if r.incref(s) < 2:
                            violations.append(("count<2 after share",
                                               tid, s))
                        mine[s] += 1
                    else:
                        s = r.try_claim(start=starts[tid % len(starts)]
                                        % nslots)
                        if s is not None:
                            mine[s] = mine.get(s, 0) + 1
                    if mine and i % 2:               # release one ref
                        s = next(iter(mine))
                        r.decref(s)
                        mine[s] -= 1
                        if not mine[s]:
                            del mine[s]

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not violations, violations
            # Counts exact after the join: allocator count == sum of the
            # references the threads actually kept, slot by slot.
            totals = [0] * nslots
            for mine in held:
                for s, k in mine.items():
                    totals[s] += k
            for s in range(nslots):
                assert r.refcount(s) == totals[s], (
                    f"slot {s}: count {r.refcount(s)} != held {totals[s]}")
            assert r.count() == sum(1 for t in totals if t)
            # Exactly-once return to the free set: draining every held
            # reference frees every slot (no zombie refs, no early free).
            for s, k in enumerate(totals):
                for j in range(k):
                    assert r.decref(s) == k - j - 1
            assert r.count() == 0
            for s in range(nslots):
                assert r.claim_specific(s), "slot not returned to free set"
