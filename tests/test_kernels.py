"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the deliverable: every kernel is asserted
allclose against its ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_trainable)
from repro.kernels.nbb_matmul import nbb_matmul

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,S,H,Hkv,hd",
    [
        (1, 128, 128, 4, 4, 64),     # MHA square
        (2, 128, 256, 8, 2, 64),     # GQA, decode-suffix (T < S)
        (1, 256, 256, 6, 3, 128),    # group=2, 128 head_dim
    ])
def test_flash_attention_matches_ref(B, T, S, H, Hkv, hd, dtype):
    q = rand(0, (B, T, H, hd), dtype)
    k = rand(1, (B, S, Hkv, hd), dtype)
    v = rand(2, (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("window", [128, 512])
def test_flash_attention_sliding_window(window):
    B, T, H, hd = 1, 512, 4, 64
    q = rand(3, (B, T, H, hd), jnp.float32)
    k = rand(4, (B, T, H, hd), jnp.float32)
    v = rand(5, (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_softcap():
    B, T, H, hd = 1, 128, 2, 64
    q = rand(6, (B, T, H, hd), jnp.float32)
    k = rand(7, (B, T, H, hd), jnp.float32)
    v = rand(8, (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, softcap=50.0, interpret=True)
    want = ref.flash_attention_ref(q, k, v, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    B, T, S, H, hd = 1, 128, 256, 4, 64
    q = rand(9, (B, T, H, hd), jnp.float32)
    k = rand(10, (B, S, H, hd), jnp.float32)
    v = rand(11, (B, S, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_grad_matches_ref():
    """interpret-mode kernel must be differentiable for training use."""
    B, T, H, hd = 1, 128, 2, 64
    q = rand(12, (B, T, H, hd), jnp.float32)
    k = rand(13, (B, T, H, hd), jnp.float32)
    v = rand(14, (B, T, H, hd), jnp.float32)

    def f_kern(q, k, v):
        return flash_attention_trainable(q, k, v, True, 0, 0.0, 128, 128,
                                         True).sum()

    def f_ref(q, k, v):
        return ref.flash_attention_ref(q, k, v).sum()

    g1 = jax.grad(f_kern, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# NBB double-buffered matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (256, 512, 256, 128, 128, 128),   # 4-deep K pipeline
    (128, 128, 128, 128, 128, 128),   # single K step (ring primes only)
    (512, 1024, 256, 256, 256, 256),
])
def test_nbb_matmul_matches_ref(M, K, N, bm, bn, bk, dtype):
    a = rand(20, (M, K), dtype)
    b = rand(21, (K, N), dtype)
    out = nbb_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b)
    # fp32 tol covers K-blocked vs single-dot accumulation-order noise.
    tol = (dict(atol=5e-4, rtol=1e-3) if dtype == jnp.float32
           else TOL[dtype])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_nbb_matmul_odd_k_depth():
    """Odd K-tile count: final slot parity differs from the primed slot."""
    a = rand(22, (128, 384), jnp.float32)
    b = rand(23, (384, 128), jnp.float32)
    out = nbb_matmul(a, b, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               atol=2e-5, rtol=2e-5)
