"""VirtualScheduler core units (DESIGN.md §15): deterministic replay,
bounded-DFS exploration, seeded fuzzing + minimization, and the
zero-overhead-unarmed guarantee of the yield-point hook.

The worlds here are deliberately tiny and self-contained (a shared
counter with an explicit load/store race) so they test the *scheduler*,
not the lock-free primitives — those are covered by the scenarios in
``repro.checker.scenarios`` (test_linearizability / test_checker_faults).
"""
import json

import pytest

from repro.core import interleave as il
from repro.core.nbb import HostNBB


def make_race_world() -> il.World:
    """Two tasks each do a non-atomic read-modify-write of a shared
    counter — the textbook lost update.  ``check`` demands both bumps
    landed, so any schedule interleaving the load/store windows fails."""
    box = {"v": 0}

    def bump() -> None:
        il.yield_point("load", None)
        v = box["v"]
        il.yield_point("store", None)
        box["v"] = v + 1

    return il.World(
        tasks=[("a", bump), ("b", bump)],
        fingerprint=lambda: box["v"],
        check=lambda: (_ for _ in ()).throw(
            AssertionError(f"lost update: v={box['v']}"))
        if box["v"] != 2 else None,
    )


def make_safe_world() -> il.World:
    """Same shape, but each bump is atomic (single yield before the
    whole RMW) — no interleaving can lose an update."""
    box = {"v": 0}

    def bump() -> None:
        il.yield_point("rmw", None)
        box["v"] += 1

    def check() -> None:
        assert box["v"] == 2

    return il.World(tasks=[("a", bump), ("b", bump)],
                    fingerprint=lambda: box["v"], check=check)


# ---------------------------------------------------------------------------
# Determinism + replay.
# ---------------------------------------------------------------------------
def test_same_schedule_same_run():
    r1 = il.run_schedule(make_race_world, [0, 1, 0, 1], strict=False)
    r2 = il.run_schedule(make_race_world, [0, 1, 0, 1], strict=False)
    assert r1.schedule == r2.schedule
    assert r1.trace == r2.trace
    assert r1.fingerprints == r2.fingerprints


def test_sequential_schedules_pass():
    # Run a fully before b (and vice versa): no lost update.  Three
    # grants finish a task: gate->load park, load->store park, store.
    for sched in ([0, 0, 0, 1, 1, 1], [1, 1, 1, 0, 0, 0]):
        res = il.run_schedule(make_race_world, sched)
        assert not res.failed, res.error


def test_interleaved_schedule_loses_update():
    # a loads, b loads (both see 0), both store 1.
    res = il.run_schedule(make_race_world, [0, 1, 0, 1], strict=False)
    assert res.failed
    assert isinstance(res.error, AssertionError)


def test_strict_replay_divergence():
    # Task 7 never exists.
    with pytest.raises(il.ReplayDivergence):
        il.run_schedule(make_race_world, [7], strict=True)


def test_tolerant_replay_skips_disabled():
    res = il.run_schedule(make_race_world, [0, 0, 0, 0, 0, 0, 1, 1],
                          strict=False)
    # The extra 0s after task a finished are skipped, not fatal.
    assert not res.failed


def test_trace_is_exposed_to_check():
    seen = {}

    def make():
        w = make_safe_world()
        inner = w.check

        def check():
            seen["trace"] = list(w.trace)
            inner()
        w.check = check
        return w

    res = il.run_schedule(make, [])
    assert not res.failed
    assert seen["trace"] == res.trace
    assert all(site == "rmw" for _, site, _ in seen["trace"])


# ---------------------------------------------------------------------------
# Exhaustive bounded DFS.
# ---------------------------------------------------------------------------
def test_explore_finds_lost_update():
    res = il.explore(make_race_world, max_executions=200)
    assert not res.ok
    cx = res.counterexample
    assert cx.error_type == "AssertionError"
    # The counterexample replays from its schedule alone.
    rerun = il.run_schedule(make_race_world, cx.schedule, strict=False)
    assert rerun.failed


def test_explore_exhausts_safe_world():
    res = il.explore(make_safe_world, max_executions=200)
    assert res.ok
    assert res.exhausted
    assert res.executions >= 2          # both first-choice branches


def test_explore_pruning_reduces_executions():
    pruned = il.explore(make_safe_world, max_executions=500, prune=True)
    full = il.explore(make_safe_world, max_executions=500, prune=False)
    assert pruned.ok and full.ok
    assert pruned.executions <= full.executions


def test_explore_budget_reported_not_exhausted():
    res = il.explore(make_race_world, max_executions=1)
    if res.ok:                           # did not stumble on the bug yet
        assert not res.exhausted


# ---------------------------------------------------------------------------
# Fuzzing: seed reproducibility + minimization.
# ---------------------------------------------------------------------------
def test_fuzz_finds_and_minimizes():
    res = il.fuzz(make_race_world, seed=7, runs=200)
    assert not res.ok
    cx = res.counterexample
    # Reproducible from (seed, run) alone — the printed repro recipe.
    rerun = il.replay_seed(make_race_world, cx.seed, cx.run)
    assert rerun.failed
    assert type(rerun.error).__name__ == cx.error_type
    # And from the minimized schedule alone.
    replay = il.run_schedule(make_race_world, cx.schedule, strict=False)
    assert replay.failed
    # Minimal lost-update interleaving: two loads before any store.
    assert len(cx.schedule) <= 4
    assert "replay:" in cx.repro("race")


def test_fuzz_clean_world_ok():
    res = il.fuzz(make_safe_world, seed=3, runs=50)
    assert res.ok
    assert res.runs == 50


def test_minimize_is_idempotent():
    failing = il.run_schedule(make_race_world, [0, 1, 0, 1], strict=False)
    m1 = il.minimize(make_race_world, failing)
    import dataclasses
    m2 = il.minimize(make_race_world,
                     dataclasses.replace(failing, schedule=m1))
    assert len(m2) <= len(m1) <= 4


# ---------------------------------------------------------------------------
# Livelock detection.
# ---------------------------------------------------------------------------
def test_livelock_flagged():
    def make():
        def spin() -> None:
            while True:
                il.yield_point("spin", None)

        return il.World(tasks=[("s", spin)])

    res = il.run_schedule(make, [], max_steps=50)
    assert res.livelocked and res.failed


# ---------------------------------------------------------------------------
# Zero-overhead unarmed: the hook must not fire outside a scheduler.
# ---------------------------------------------------------------------------
def test_unarmed_hot_path_zero_hits():
    assert il._active is None
    before = il.ARMED_HITS
    ring = HostNBB(8)
    for i in range(1000):
        ring.insert_item(i)
        ring.read_item()
    assert il.ARMED_HITS == before
    assert il._active is None


def test_armed_hits_counted():
    before = il.ARMED_HITS
    res = il.run_schedule(make_safe_world, [])
    assert not res.failed
    assert il.ARMED_HITS - before == len(res.trace) > 0
    assert il._active is None            # disarmed after the run


# ---------------------------------------------------------------------------
# Schedule corpus serialization.
# ---------------------------------------------------------------------------
def test_schedule_roundtrip(tmp_path):
    p = tmp_path / "s.json"
    il.save_schedule(p, scenario="race", schedule=[0, 1, 0, 1],
                     expect="violation", note="lost update", seed=7)
    rec = il.load_schedule(p)
    assert rec["scenario"] == "race"
    assert rec["schedule"] == [0, 1, 0, 1]
    assert rec["expect"] == "violation"
    assert rec["seed"] == 7


def test_schedule_expect_validated(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"scenario": "x", "schedule": [],
                             "expect": "maybe"}))
    with pytest.raises(ValueError):
        il.load_schedule(p)
