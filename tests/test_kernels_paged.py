"""Paged-attention kernel vs its jnp oracle (interpret mode on CPU):
block-table indirection, causal masking to each row's true length,
page-boundary extents, GQA group sizes, and garbage-page immunity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention

jax.config.update("jax_platform_name", "cpu")


def _setup(seed, B, T, H, Hkv, hd, n_pages, ps, P, lens):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, hd)), jnp.float32)
    # rows own disjoint scattered pages — the pool allocator's invariant
    block = jnp.asarray(rng.permutation(n_pages)[:B * P].reshape(B, P),
                        jnp.int32)
    return q, kp, vp, block, jnp.asarray(lens, jnp.int32)


def _check(q, kp, vp, block, lens):
    out = paged_attention(q, kp, vp, block, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, block, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    return out


def test_decode_single_query():
    """T=1 against scattered pages — the steady-state decode shape."""
    _check(*_setup(0, B=3, T=1, H=4, Hkv=2, hd=32, n_pages=32, ps=8, P=4,
                   lens=[9, 1, 31]))


def test_chunk_query_causal_within_chunk():
    """T=8 (a prompt chunk): later chunk tokens see earlier ones, all
    masked to the row's true extent."""
    _check(*_setup(1, B=2, T=8, H=4, Hkv=4, hd=16, n_pages=24, ps=8, P=3,
                   lens=[8, 20]))


@pytest.mark.parametrize("H,Hkv", [(8, 1), (6, 2), (9, 3)])
def test_gqa_group_sizes(H, Hkv):
    _check(*_setup(2, B=2, T=1, H=H, Hkv=Hkv, hd=16, n_pages=16, ps=4, P=4,
                   lens=[5, 13]))


@pytest.mark.parametrize("length", [7, 8, 9])
def test_page_boundary_extents(length):
    """Rows ending just before / exactly at / just past a page boundary
    (ps=8) mask precisely to their extent."""
    _check(*_setup(3, B=1, T=1, H=2, Hkv=2, hd=16, n_pages=8, ps=8, P=4,
                   lens=[length]))


def test_garbage_pages_cannot_leak():
    """Entries past a row's extent point at pages FULL of other data;
    the output must depend only on the row's own prefix."""
    q, kp, vp, block, lens = _setup(4, B=2, T=1, H=4, Hkv=2, hd=16,
                                    n_pages=32, ps=4, P=8, lens=[6, 10])
    out = paged_attention(q, kp, vp, block, lens, interpret=True)
    # Redirect every out-of-extent block entry to a poison page.
    poison = jnp.full((1,) + kp.shape[1:], 1e4, kp.dtype)
    kp2 = jnp.concatenate([kp, poison])
    vp2 = jnp.concatenate([vp, poison])
    used = (np.asarray(lens)[:, None] > np.arange(8) * 4)
    block2 = jnp.asarray(np.where(used, np.asarray(block), kp.shape[0]),
                         jnp.int32)
    out2 = paged_attention(q, kp2, vp2, block2, lens, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_zero_length_row_outputs_zero():
    """A free slot (lens=0, block row all zeros) is fully masked: the
    kernel emits exact zeros instead of softmax-of-nothing garbage."""
    q, kp, vp, block, lens = _setup(5, B=2, T=1, H=2, Hkv=2, hd=16,
                                    n_pages=16, ps=4, P=4, lens=[0, 11])
    block = block.at[0].set(0)
    out = _check(q, kp, vp, block, lens)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
