"""Linearizability validation of the lock-free core (DESIGN.md §15).

Three layers:

* Wing & Gong checker units over hand-built histories — the checker
  must accept classic legal overlaps and reject classic illegal ones
  independent of any scheduler.
* Sequential-spec units — the documented spec-strength decisions
  (strict SPSC/FSM, weak scan refusals, weak partial bursts).
* Exhaustive scenario exploration at tier-1 budgets — every interleaving
  of the bounded casts over HostNBB, MpscQueue, HostBitset,
  RefCountArray, StateCell, OpHandle and PriorityTransport is
  linearizable; the two deliberately broken scenarios are convicted.
"""
import pytest

from repro.checker import lin, scenarios, specs
from repro.checker.lin import MISSING, OpRecord, Recorder, ops_from_history
from repro.core import states


# ---------------------------------------------------------------------------
# Wing & Gong units on hand histories.
# ---------------------------------------------------------------------------
def test_sequential_history_linearizable():
    ops = ops_from_history([
        ("p", "send", (1,), "OK"),
        ("c", "recv", (), ("OK", 1)),
        ("c", "recv", (), ("EMPTY", None)),
    ])
    res = lin.check_history(ops, specs.SpscRingSpec(2))
    assert res.ok
    assert res.linearization == (0, 1, 2)


def test_overlapping_ops_reordered():
    # recv overlaps send and returns its item: legal — linearize send
    # first even though recv was invoked earlier.
    ops = [
        OpRecord(op="recv", args=(), result=("OK", 5), inv=0, res=3,
                 task="c"),
        OpRecord(op="send", args=(5,), result="OK", inv=1, res=2,
                 task="p"),
    ]
    assert lin.check_history(ops, specs.SpscRingSpec(2)).ok


def test_value_from_the_future_rejected():
    # recv COMPLETED before send was invoked: no legal order.
    ops = [
        OpRecord(op="recv", args=(), result=("OK", 5), inv=0, res=1,
                 task="c"),
        OpRecord(op="send", args=(5,), result="OK", inv=2, res=3,
                 task="p"),
    ]
    res = lin.check_history(ops, specs.SpscRingSpec(2))
    assert not res.ok
    assert "NOT linearizable" in res.explain()


def test_pending_op_may_take_effect_or_dangle():
    # A send with no response (task died) may still explain a recv...
    ops = [
        OpRecord(op="send", args=(9,), result=MISSING, inv=0, res=None,
                 task="p"),
        OpRecord(op="recv", args=(), result=("OK", 9), inv=1, res=2,
                 task="c"),
    ]
    assert lin.check_history(ops, specs.SpscRingSpec(2)).ok
    # ... or dangle forever without invalidating an EMPTY.
    ops2 = [
        OpRecord(op="send", args=(9,), result=MISSING, inv=0, res=None,
                 task="p"),
        OpRecord(op="recv", args=(), result=("EMPTY", None), inv=1,
                 res=2, task="c"),
    ]
    assert lin.check_history(ops2, specs.SpscRingSpec(2)).ok


def test_strict_empty_refusal_rejected_when_full():
    ops = ops_from_history([
        ("p", "send", (1,), "OK"),
        ("c", "recv", (), ("EMPTY", None)),
    ])
    assert not lin.check_history(ops, specs.SpscRingSpec(2)).ok


def test_fsm_cas_strictness():
    spec = specs.FsmSpec(states.OP_TRANSITIONS, states.OP_PENDING)
    # Two racing CAS: exactly one may win.
    both_win = ops_from_history([
        ("a", "cas", (states.OP_PENDING, states.OP_COMPLETED), True),
        ("b", "cas", (states.OP_PENDING, states.OP_CANCELLED), True),
    ])
    assert not lin.check_history(both_win, spec).ok
    one_wins = ops_from_history([
        ("a", "cas", (states.OP_PENDING, states.OP_COMPLETED), True),
        ("b", "cas", (states.OP_PENDING, states.OP_CANCELLED), False),
        ("r", "read", (), states.OP_COMPLETED),
    ])
    assert lin.check_history(one_wins, spec).ok
    # A CAS linearized in its expected state MUST win: sequential
    # cas(PENDING->COMPLETED)=False on a fresh cell is illegal.
    must_win = ops_from_history([
        ("a", "cas", (states.OP_PENDING, states.OP_COMPLETED), False),
    ])
    assert not lin.check_history(must_win, spec).ok


def test_recorder_roundtrip():
    rec = Recorder()
    a = rec.invoke("t", "send", 1)
    b = rec.invoke("u", "recv")
    rec.respond(b, ("OK", 1))
    rec.respond(a, "OK")
    ops = rec.ops()
    assert [o.op for o in ops] == ["send", "recv"]
    assert ops[0].inv < ops[1].inv < ops[1].res < ops[0].res
    pending = rec.invoke("t", "send", 2)
    assert rec.ops()[pending].res is None
    assert rec.ops()[pending].result == MISSING


def test_search_budget_guard():
    ops = ops_from_history(
        [("t", "send", (i,), "OK") for i in range(12)])
    with pytest.raises(RuntimeError, match="exceeded"):
        lin.check_history(ops, specs.SpscRingSpec(64), max_states=4)


# ---------------------------------------------------------------------------
# Spec-strength decisions.
# ---------------------------------------------------------------------------
def test_weak_scan_refusal_admitted():
    # try_claim -> None with free slots: weak refusal, linearizable.
    ops = ops_from_history([("t", "try_claim", (), None)])
    assert lin.check_history(ops, specs.BitsetSpec(2)).ok
    assert lin.check_history(
        ops_from_history([("t", "try_claim", (), None)]),
        specs.RefCountSpec(2)).ok


def test_weak_partial_burst_admitted():
    # (FULL, 1) for a 2-item burst into an EMPTY 3-slot ring: admitted
    # (the occupancy snapshot predates a drain; see specs docstring) —
    # but the accepted prefix must still surface.
    spec = specs.SpscRingSpec(3)
    ok = ops_from_history([
        ("p", "send_burst", ((0, 1),), ("FULL", 1)),
        ("c", "drain", (4,), (0,)),
    ])
    assert lin.check_history(ok, spec).ok
    bad = ops_from_history([
        ("p", "send_burst", ((0, 1),), ("FULL", 1)),
        ("c", "drain", (4,), (0, 1)),    # item 1 was never accepted
    ])
    assert not lin.check_history(bad, spec).ok


def test_strict_full_acceptance_and_refusal():
    spec = specs.SpscRingSpec(2)
    # OK must mean ALL items landed.
    assert not lin.check_history(ops_from_history([
        ("p", "send_burst", ((0, 1, 2),), ("OK", 3)),
    ]), spec).ok
    # (FULL, 0) only in a truly full ring.
    assert not lin.check_history(ops_from_history([
        ("p", "send_burst", ((0,),), ("FULL", 0)),
    ]), spec).ok


# ---------------------------------------------------------------------------
# Exhaustive model checking of the real primitives (tier-1 budgets).
# The exhausted=True scenarios are full proofs over their bounded casts.
# ---------------------------------------------------------------------------
EXHAUSTIVE = ["spsc_scalar", "spsc_burst", "bitset_hammer",
              "statecell_cas", "statecell_compaction", "ophandle_cancel",
              "priority_scan"]


@pytest.mark.parametrize("name", EXHAUSTIVE)
def test_scenario_exhaustive(name):
    r = scenarios.explore_scenario(name)
    assert r.ok, (f"{name}: {r.counterexample.error}\n"
                  f"repro schedule: {list(r.counterexample.schedule)}")
    assert r.exhausted, f"{name}: budget too small for exhaustion"


@pytest.mark.parametrize("name,budget", [
    ("mpsc_fanin", 1500),
    ("refcount_claim", 1500),
    ("refcount_share", 1500),
])
def test_scenario_bounded(name, budget):
    # Too large to exhaust in tier-1; full budgets run in bench_check.
    r = scenarios.explore_scenario(name, max_executions=budget)
    assert r.ok, (f"{name}: {r.counterexample.error}\n"
                  f"repro schedule: {list(r.counterexample.schedule)}")


def test_legacy_statecell_convicted():
    r = scenarios.explore_scenario("legacy_statecell_compaction")
    assert not r.ok
    assert r.counterexample.error_type == "LinearizabilityViolation"


def test_broken_ring_convicted():
    r = scenarios.explore_scenario("broken_ring")
    assert not r.ok
    assert r.counterexample.error_type == "TornReadDetected"


def test_fuzz_smoke_on_scenarios():
    for name in ("spsc_scalar", "statecell_compaction"):
        f = scenarios.fuzz_scenario(name, seed=0, runs=25)
        assert f.ok, f"{name}: {f.counterexample.error}"
