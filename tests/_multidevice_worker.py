"""Multi-device checks, run in a subprocess so the 8-device XLA flag never
leaks into the main pytest process (see dryrun.py note on device counts).

Exit code 0 = all checks pass.  Invoked by test_pipeline.py.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.pipeline import pipeline_apply, pipeline_reference
from repro.parallel.compression import compressed_psum
from repro.parallel.sharding import shard_map_compat


def check_pipeline_schedules():
    mesh = jax.make_mesh((8,), ("stage",))
    n_stages, n_micro, mb, d = 8, 12, 4, 16

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    k = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(k, (n_stages, d, d), jnp.float32) * 0.3,
        "b": jax.random.normal(jax.random.fold_in(k, 1),
                               (n_stages, d), jnp.float32) * 0.1,
    }
    mbs = jax.random.normal(jax.random.fold_in(k, 2),
                            (n_micro, mb, d), jnp.float32)
    want = pipeline_reference(stage_fn, params, mbs, n_stages)
    for schedule in ("barrier", "nbb", "nbb2"):
        got = pipeline_apply(stage_fn, params, mbs, mesh, axis="stage",
                             schedule=schedule)[-1]   # last stage's slab
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"schedule={schedule}")
    print("pipeline schedules OK")


def check_pipeline_collective_bytes():
    """nbb must move ~1/S the collective bytes of barrier (paper's point)."""
    import re
    mesh = jax.make_mesh((8,), ("stage",))
    n_stages, n_micro, mb, d = 8, 8, 4, 128

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    params = {"w": jnp.zeros((n_stages, d, d), jnp.float32)}
    mbs = jnp.zeros((n_micro, mb, d), jnp.float32)

    def bytes_for(schedule):
        f = jax.jit(lambda p, m: pipeline_apply(
            stage_fn, p, m, mesh, axis="stage", schedule=schedule))
        hlo = f.lower(params, mbs).compile().as_text()
        total = 0
        for line in hlo.splitlines():
            m = re.search(r"=\s+f32\[([\d,]+)\]\S*\s+(all-gather|"
                          r"collective-permute)\(", line)
            if m:
                n = 1
                for dd in m.group(1).split(","):
                    n *= int(dd)
                total += 4 * n
        return total

    b_barrier, b_nbb = bytes_for("barrier"), bytes_for("nbb")
    assert b_nbb * 4 < b_barrier, (b_nbb, b_barrier)
    print(f"collective bytes: barrier={b_barrier} nbb={b_nbb} "
          f"ratio={b_barrier / max(b_nbb, 1):.1f}x OK")


def check_compressed_psum():
    mesh = jax.make_mesh((8,), ("data",))
    k = jax.random.PRNGKey(3)
    # per-shard gradients [8, ...]
    g_sh = jax.random.normal(k, (8, 32, 16), jnp.float32)

    def body(g, e):
        # local leaves are [1, 32, 16] (leading shard dim); strip it
        mean, new_e = compressed_psum({"w": g[0]}, {"w": e[0]}, "data",
                                      n_shards=8)
        return mean["w"], new_e["w"][None]

    f = shard_map_compat(body, mesh=mesh, in_specs=(P("data"), P("data")),
                         out_specs=(P(), P("data")))
    err = jnp.zeros((8, 32, 16), jnp.float32)
    mean, err1 = f(g_sh, err)
    true_mean = g_sh.mean(0)
    q_err = np.abs(np.asarray(mean) - np.asarray(true_mean)).max()
    amax = float(jnp.abs(g_sh).max())
    assert q_err <= amax / 127.0 + 1e-6, (q_err, amax / 127.0)
    # error feedback telescopes: two steps of same grad ~ exact in sum
    mean2, err2 = f(g_sh, err1)
    two_step = (np.asarray(mean) + np.asarray(mean2))
    np.testing.assert_allclose(two_step, 2 * np.asarray(true_mean),
                               atol=2 * amax / 127.0 + 1e-5)
    print("compressed_psum OK")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_pipeline_schedules()
    check_pipeline_collective_bytes()
    check_compressed_psum()
    print("ALL MULTIDEVICE CHECKS PASSED")
