"""Checker x FaultPlan composition (DESIGN.md §15): the PR-8 torn-span
recovery paths model-checked at every reachable interleaving point.

``torn_span_recovery`` injects ``transport.stall`` through a FaultPlan
(the producer dies mid-span-reservation with an odd update counter and
written-but-uncommitted slots), runs a concurrent consumer and a
recovery task (``recover_ring`` + resume), and asserts under EVERY
schedule: committed-prefix-only delivery, no torn reads, and an even
counter after rollback.  ``mpsc_dead_producer`` checks fan-in isolation:
a dead producer's span never leaks and never disturbs its siblings.
"""
from repro.checker import scenarios
from repro.core import faults, interleave as il


def test_torn_span_recovery_exhaustive():
    r = scenarios.explore_scenario("torn_span_recovery")
    assert r.ok, (f"{r.counterexample.error}\n"
                  f"repro schedule: {list(r.counterexample.schedule)}")
    assert r.exhausted, "budget too small: raise explore_budget"


def test_mpsc_dead_producer_bounded():
    r = scenarios.explore_scenario("mpsc_dead_producer",
                                   max_executions=1500)
    assert r.ok, (f"{r.counterexample.error}\n"
                  f"repro schedule: {list(r.counterexample.schedule)}")


def test_recovery_path_reachable():
    """At least one schedule walks the FULL fault path (stall observed,
    ring rolled back, service resumed) — guards against the scenario
    silently never reaching the code under test."""
    sched = [0] * 40 + [2] * 20 + [1] * 20
    res = il.run_schedule(scenarios.get("torn_span_recovery").make_world,
                          sched, max_steps=600, strict=False)
    assert not res.failed, res.error
    sites = [s for _, s, _ in res.trace]
    assert "reaper.resend" in sites      # recover_ring ran and resent


def test_stall_fires_under_scheduler_control():
    """The FaultPlan's nth-probe counting is deterministic under the
    scheduler: the first burst commits, the second stalls."""
    seen = []

    def make():
        w = scenarios.get("torn_span_recovery").make_world()
        inner = w.check

        def check():
            seen.append(True)
            inner()
        w.check = check
        return w

    res = il.run_schedule(make, [0] * 40, strict=False, max_steps=600)
    assert not res.failed, res.error
    assert seen


def test_fuzz_fault_scenarios():
    for name in ("torn_span_recovery", "mpsc_dead_producer"):
        f = scenarios.fuzz_scenario(name, seed=1, runs=20)
        assert f.ok, (f"{name}: {f.counterexample.error}\n"
                      f"repro: {f.counterexample.repro(name)}")


def test_injected_fault_is_not_swallowed_by_scheduler():
    """A task that does NOT catch its InjectedFault surfaces it as the
    run error (with its schedule) rather than hanging or vanishing."""
    def make():
        from repro.core.nbb import HostNBB
        from repro.core.transport import FaultyTransport
        ring = HostNBB(4)
        plan = faults.FaultPlan(
            [faults.FaultRule(site="transport.stall", nth=1)], name="s")
        ft = FaultyTransport(ring, plan, name="t")

        def producer() -> None:
            ft.send_burst([1, 2])        # uncaught InjectedFault

        return il.World(tasks=[("p", producer)])

    res = il.run_schedule(make, [], max_steps=200)
    assert res.failed
    assert isinstance(res.error, faults.InjectedFault)
