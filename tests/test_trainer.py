"""Trainer / checkpoint / data-pipeline integration tests (CPU, tiny model)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, synth_batch
from repro.models.model import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamW, OptConfig
from repro.train.trainer import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture()
def tiny():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    opt = AdamW(OptConfig(lr=1e-3, warmup_steps=2, total_steps=100))
    return cfg, model, opt


def _pipe(cfg, batch=2, seq=16):
    return DataPipeline(batch=batch, seq_len=seq, vocab=cfg.vocab_size,
                        nproducers=2, seed=0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": {"c": jnp.ones((2,), jnp.int32)}}
    ckpt_lib.save(tmp_path, 7, state)
    step, restored = ckpt_lib.restore(tmp_path, state)
    assert step == 7
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt_lib.save(tmp_path, s, state, keep=2)
    assert ckpt_lib.latest_step(tmp_path) == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    state = {"a": jnp.arange(4.0)}
    d = ckpt_lib.save(tmp_path, 1, state)
    leaf = next(d.glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="CRC"):
        ckpt_lib.restore(tmp_path, state)


def test_checkpoint_shape_mismatch(tmp_path):
    ckpt_lib.save(tmp_path, 1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(tmp_path, {"a": jnp.zeros((5,))})


def test_async_checkpointer_saves_latest(tmp_path):
    ck = ckpt_lib.AsyncCheckpointer(tmp_path, keep=2, poll_s=0.001)
    for s in range(5):
        ck.publish(s, {"x": jnp.full((2,), float(s))})
    ck.close()
    latest = ckpt_lib.latest_step(tmp_path)
    assert latest == 4  # newest publish always lands (NBW freshest-wins)
    _, restored = ckpt_lib.restore(tmp_path, {"x": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(restored["x"]), 4.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synth_batch_deterministic():
    a = synth_batch(0, 1, 2, 4, 8, 100)
    b = synth_batch(0, 1, 2, 4, 8, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(0, 1, 3, 4, 8, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_delivers_unique_batches():
    pipe = DataPipeline(batch=2, seq_len=8, vocab=1000, nproducers=3,
                        seed=0, depth=4)
    try:
        seen = set()
        for _ in range(20):
            b = pipe.get()
            assert b["tokens"].shape == (2, 8)
            seen.add(b["tokens"].tobytes())
        assert len(seen) == 20  # exactly-once delivery
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------
def test_trainer_loss_decreases(tiny, tmp_path):
    cfg, model, opt = tiny
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                       log_every=5, async_checkpoint=False)
    tr = Trainer(model, opt, tc)
    pipe = _pipe(cfg)
    try:
        hist = tr.fit(pipe, steps=30)
    finally:
        pipe.close()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first, (first, last)


def test_trainer_checkpoint_restart_exact(tiny, tmp_path):
    """Restart from a checkpoint reproduces the uninterrupted run exactly
    (same data order via the deterministic stream, same params)."""
    cfg, model, opt = tiny

    def batches():
        s = 0
        while True:
            yield synth_batch(0, 0, s, 2, 16, cfg.vocab_size)
            s += 1

    tc = TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5,
                       async_checkpoint=False)
    tr = Trainer(model, opt, tc, rng=jax.random.PRNGKey(7))
    gen = batches()
    tr.fit(gen, steps=10)
    p_ref = jax.device_get(tr.params)

    # interrupted twin: 5 steps, "crash", resume, 5 more on the same stream
    tc2 = TrainerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
                        async_checkpoint=False)
    tr2 = Trainer(model, opt, tc2, rng=jax.random.PRNGKey(7))
    gen2 = batches()
    tr2.fit(gen2, steps=5)
    del tr2
    tr3 = Trainer(model, opt, tc2, rng=jax.random.PRNGKey(999), resume=True)
    assert tr3.step == 5
    gen3 = batches()
    for _ in range(5):   # replay consumed prefix (deterministic stream)
        next(gen3)
    tr3.fit(gen3, steps=5)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(tr3.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_trainer_straggler_detection(tiny, tmp_path):
    cfg, model, opt = tiny
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                       straggler_factor=2.0, async_checkpoint=False)
    tr = Trainer(model, opt, tc)

    def batches():
        s = 0
        while True:
            if s == 8:  # inject one slow step (data stall)
                time.sleep(1.0)
            yield synth_batch(0, 0, s, 2, 16, cfg.vocab_size)
            s += 1

    tr.fit(batches(), steps=12)
    assert tr.straggler_steps >= 1


def test_trainer_telemetry_nbw(tiny, tmp_path):
    cfg, model, opt = tiny
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                       async_checkpoint=False)
    tr = Trainer(model, opt, tc)

    def batches():
        s = 0
        while True:
            yield synth_batch(0, 0, s, 2, 16, cfg.vocab_size)
            s += 1

    tr.fit(batches(), steps=3)
    assert tr.telemetry["step"].read() == 3
    assert np.isfinite(tr.telemetry["loss"].read())
