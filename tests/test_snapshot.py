"""Unit tests for the snapshot layer itself (DESIGN.md §14): the
torn-write-safe file protocol, the write-ahead intake journal's torn-tail
recovery, non-destructive ring peeking, and FSM-cell pickling — the
pieces ``test_serve_recovery.py`` exercises end-to-end, isolated here so
a protocol regression points at the file format, not the engine.
"""
import pickle

import numpy as np
import pytest

from repro.core import states
from repro.core.faults import FaultPlan, FaultRule
from repro.core.host_queue import SpscQueue
from repro.core.nbb import HostNBB
from repro.serve.snapshot import (EngineSnapshot, IntakeJournal,
                                  SnapshotError, load_latest, peek_ring,
                                  read_snapshot, write_snapshot)


def _snap(tag=0):
    return EngineSnapshot(
        config={"tag": tag}, journal_seq=0, next_req_id=7,
        pool={"n_pages": 4}, prefix_entries=[], slots=[],
        cur=np.arange(2, dtype=np.int32), pos=np.zeros(2, np.int32),
        parked=[], deferred=[], queued=[], undelivered={},
        stats={"served": tag})


class TestSnapshotFiles:
    def test_write_read_roundtrip(self, tmp_path):
        path = write_snapshot(_snap(3), str(tmp_path))
        got = read_snapshot(path)
        assert got.config == {"tag": 3} and got.next_req_id == 7
        assert np.array_equal(got.cur, np.arange(2, dtype=np.int32))

    def test_truncated_file_rejected(self, tmp_path):
        path = write_snapshot(_snap(), str(tmp_path))
        blob = open(path, "rb").read()
        for cut in (0, 3, len(blob) // 2, len(blob) - 1):
            with open(path, "wb") as f:
                f.write(blob[:cut])
            with pytest.raises(SnapshotError):
                read_snapshot(path)

    def test_bit_flip_rejected(self, tmp_path):
        path = write_snapshot(_snap(), str(tmp_path))
        blob = bytearray(open(path, "rb").read())
        blob[-1] ^= 0xFF
        with open(path, "wb") as f:
            f.write(bytes(blob))
        with pytest.raises(SnapshotError, match="checksum"):
            read_snapshot(path)

    def test_load_latest_skips_torn_newest(self, tmp_path):
        d = str(tmp_path)
        write_snapshot(_snap(1), d)
        good = write_snapshot(_snap(2), d)
        # A fault plan that tears the NEXT write at the final name —
        # exactly a crash mid-checkpoint.
        plan = FaultPlan([FaultRule("snapshot.write", nth=1)])
        assert write_snapshot(_snap(3), d, faults=plan) is None
        snap, path = load_latest(d)
        assert path == good and snap.config == {"tag": 2}

    def test_load_latest_empty_dir(self, tmp_path):
        assert load_latest(str(tmp_path / "nowhere")) == (None, None)

    def test_prunes_to_keep_newest(self, tmp_path):
        d = str(tmp_path)
        for i in range(12):
            write_snapshot(_snap(i), d, keep=8)
        snap, _ = load_latest(d)
        assert snap.config == {"tag": 11}
        import os
        kept = [n for n in os.listdir(d) if n.endswith(".ckpt")]
        assert len(kept) == 8


class TestIntakeJournal:
    def test_append_reopen_roundtrip(self, tmp_path):
        p = str(tmp_path / "j.wal")
        j = IntakeJournal(p)
        for i in range(5):
            assert j.append({"req_id": i,
                             "prompt": np.arange(i + 1)}) == i
        j.close()
        j2 = IntakeJournal(p)
        assert j2.seq == 5
        assert [r["req_id"] for r in j2.records] == list(range(5))
        assert np.array_equal(j2.records[3]["prompt"], np.arange(4))
        j2.close()

    def test_torn_tail_truncated_then_appendable(self, tmp_path):
        p = str(tmp_path / "j.wal")
        j = IntakeJournal(p)
        j.append({"req_id": 0})
        j.append({"req_id": 1})
        j.close()
        with open(p, "ab") as f:        # a crash mid-append: garbage tail
            f.write(b"\x13\x00\x00\x00torn-record-garbag")
        j2 = IntakeJournal(p)
        assert j2.seq == 2              # tail dropped, good prefix kept
        j2.append({"req_id": 2})        # and the log is appendable again
        j2.close()
        j3 = IntakeJournal(p)
        assert [r["req_id"] for r in j3.records] == [0, 1, 2]
        j3.close()

    def test_empty_and_fresh_files(self, tmp_path):
        p = str(tmp_path / "sub" / "j.wal")
        j = IntakeJournal(p)            # creates the parent dir
        assert j.seq == 0 and j.records == []
        j.close()
        j2 = IntakeJournal(p)           # zero-length file reopens clean
        assert j2.seq == 0
        j2.close()


class TestPeekRing:
    def test_peek_is_nondestructive_and_ordered(self):
        q = SpscQueue(8)
        for i in range(5):
            q.insert_item(i)
        assert peek_ring(q) == [0, 1, 2, 3, 4]
        assert peek_ring(q) == [0, 1, 2, 3, 4]     # still all there
        assert q.read_item()[1] == 0               # consumer unaffected
        assert peek_ring(q) == [1, 2, 3, 4]

    def test_peek_wraps_and_sees_empty(self):
        q = HostNBB(4)
        assert peek_ring(q) == []
        for i in range(4):
            q.insert_item(i)
        for i in range(3):                          # force index wrap
            q.read_item()
            q.insert_item(10 + i)
        assert peek_ring(q) == [3, 10, 11, 12]


class TestStateCellPickle:
    def test_roundtrip_preserves_table_identity(self):
        cell = states.request_cell("r")
        cell.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        cell.transition(states.REQUEST_VALID, states.REQUEST_RECEIVED)
        c2 = pickle.loads(pickle.dumps(cell))
        assert c2.state == states.REQUEST_RECEIVED
        assert c2._table is states.REQUEST_TRANSITIONS
        # The journal compacts away: the restored cell starts from the
        # folded state, with full transition authority going forward.
        assert c2._journal == []
        assert c2.cas(states.REQUEST_RECEIVED, states.REQUEST_CANCELLED)
        assert c2.state == states.REQUEST_CANCELLED

    def test_buffer_cell_roundtrip(self):
        cell = states.buffer_cell("b")
        cell.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        cell.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        c2 = pickle.loads(pickle.dumps(cell))
        assert c2.state == states.BUFFER_ALLOCATED
        assert c2._table is states.BUFFER_TRANSITIONS

    def test_noncanonical_table_refuses_pickle(self):
        cell = states.StateCell({0: {1}}, 0, name="odd")
        with pytest.raises(TypeError):
            pickle.dumps(cell)
