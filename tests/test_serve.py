"""Serving engine + paged KV pool tests (CPU, tiny model)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import states
from repro.models.model import build_model
from repro.serve.engine import (OversizeStatus, ServeEngine, TimeoutStatus,
                                pack_token_event, unpack_token_event)
from repro.serve.kv_cache import OK, POOL_FULL, PagedKVPool

jax.config.update("jax_platform_name", "cpu")


def _occupancy(pool):
    """Pool stats minus the monotonic traffic counters (kv_copy_bytes,
    resident peak): the stable "pages not leaked" comparison."""
    s = pool.stats()
    s.pop("kv_copy_bytes")
    s.pop("kv_resident_bytes_peak")
    return s


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------
def test_pool_admit_grow_free():
    pool = PagedKVPool(8, page_size=4, n_layers=2, kv_heads=2, head_dim=8)
    assert pool.try_admit(0, 10) == OK          # 3 pages
    assert pool.free_pages() == 5
    assert pool.grow(0, 13) == OK               # 4th page
    assert pool.free_pages() == 4
    assert pool.try_admit(1, 17) == POOL_FULL   # needs 5 > 4 free
    assert pool.free_pages() == 4               # all-or-nothing rollback
    pool.free(0)
    assert pool.free_pages() == 8
    assert pool.try_admit(1, 17) == OK


def test_pool_swap_roundtrip():
    pool = PagedKVPool(8, page_size=4, n_layers=3, kv_heads=2, head_dim=8,
                       dtype=jnp.float32)
    n_tok = 10
    assert pool.try_admit(5, n_tok) == OK
    k = jax.random.normal(jax.random.PRNGKey(0), (n_tok, 3, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(1), (n_tok, 3, 2, 8))
    assert pool.swap_out(5, k, v, n_tok) == OK
    k2, v2 = pool.swap_in(5, max_len=16)
    np.testing.assert_allclose(np.asarray(k2[:n_tok]), np.asarray(k))
    np.testing.assert_allclose(np.asarray(v2[:n_tok]), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(k2[n_tok:]), 0)


def test_pool_concurrent_admission_lock_free():
    """Many threads racing for pages: exactly-once claims, no deadlock."""
    pool = PagedKVPool(64, page_size=1, n_layers=1, kv_heads=1, head_dim=2)
    results = []

    def worker(tid):
        got = pool.try_admit(tid, 4)   # 4 pages each
        results.append((tid, got))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    admitted = [tid for tid, s in results if s == OK]
    assert len(admitted) == 16          # 64 pages / 4 per seq
    # each admitted seq owns disjoint pages
    seen = set()
    for tid in admitted:
        pages = pool.table(tid).pages
        assert len(pages) == 4
        assert not (set(pages) & seen)
        seen |= set(pages)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_single_request(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1)
    req = eng.submit(0, np.arange(5) % cfg.vocab_size, max_tokens=4)
    assert req is not None
    served = eng.step()
    assert served == 1
    resp = eng.get_response(0, timeout_s=10)
    assert resp is not None
    assert resp.fsm.state == states.REQUEST_COMPLETED
    assert resp.tokens_out.shape == (4,)
    assert ((resp.tokens_out >= 0) & (resp.tokens_out < cfg.vocab_size)).all()
    assert eng.pool.free_pages() == eng.pool.n_pages  # pages returned


def test_engine_batches_multiple_clients(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=4, max_len=32, n_clients=3)
    reqs = [eng.submit(c, np.arange(3 + c) % cfg.vocab_size, max_tokens=3)
            for c in range(3)]
    assert all(r is not None for r in reqs)
    eng.step()
    assert eng.stats["served"] == 3
    assert eng.stats["batches"] == 1   # one fused batch
    for c in range(3):
        resp = eng.get_response(c, timeout_s=10)
        assert resp is not None and resp.client_id == c
        assert len(resp.tokens_out) == 3


def test_engine_eos_stops_early(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=32, n_clients=1)
    # discover the greedy first token, then use it as EOS
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=6)
    eng.step()
    first = eng.get_response(0, timeout_s=10).tokens_out[0]
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=6,
               eos_id=int(first))
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert len(resp.tokens_out) == 1           # stopped at EOS immediately


def test_engine_rejects_when_pool_full(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1,
                      pool_pages=2, page_size=4)   # 8 tokens of KV total
    eng.submit(0, np.arange(6) % cfg.vocab_size, max_tokens=8)
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert resp.fsm.state == states.REQUEST_CANCELLED
    assert eng.stats["rejected"] == 1
    assert eng.pool.free_pages() == 2          # nothing leaked


def test_slot_engine_mixed_lengths_no_convoy(engine_setup):
    """Iteration-level batching: short requests flow through a slot while
    a long generation keeps decoding — fewer decode steps than the wave
    scheduler's sum of per-wave maxima."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    lengths = [12, 2, 2, 2]            # long first: occupies slot 0
    for n in lengths:
        assert eng.submit(0, np.arange(4) % cfg.vocab_size,
                          max_tokens=n) is not None
    served = eng.step()
    assert served == 4
    # Wave scheduling would convoy: waves [12,2] + [2,2] = 14+ steps.
    # Slot swap: the long sequence bounds the busy period (~12 steps).
    assert eng.stats["decode_steps"] < 14, eng.stats
    assert eng.stats["served"] == 4 and eng.stats["rejected"] == 0
    got = sorted(len(eng.get_response(0, 10).tokens_out) for _ in range(4))
    assert got == sorted(lengths)
    assert eng.pool.free_pages() == eng.pool.n_pages
    assert 0.0 < eng.occupancy() <= 1.0


def test_slot_engine_fifo_per_client(engine_setup):
    """Slot-swap batcher admits in per-client submission order: with one
    slot, responses complete strictly in FIFO order."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=32, n_clients=1,
                      pool_pages=256, scheduler="slot")
    ids = [eng.submit(0, np.arange(3) % cfg.vocab_size, max_tokens=2).req_id
           for _ in range(3)]
    eng.step()
    got = [eng.get_response(0, 10).req_id for _ in range(3)]
    assert got == ids, "per-client FIFO violated by slot batcher"


def test_slot_fsm_lifecycle_and_illegal_transitions(engine_setup):
    """Every slot ends a drained step FREE; driving a slot FSM through an
    illegal transition still raises (the Figure-4 cell is live)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1,
                      pool_pages=256, scheduler="slot")
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=2)
    eng.step()
    eng.get_response(0, 10)
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE
        assert slot.request is None
    with pytest.raises(states.IllegalTransition):
        eng.slots[0].fsm.cas(states.BUFFER_FREE, states.BUFFER_RECEIVED)


def test_slot_engine_admits_while_decoding(engine_setup):
    """A request submitted mid-generation is swapped in without waiting
    for the running sequence to finish (no wave barrier)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=10)
    # Run a few ticks: the long request is mid-decode.
    for _ in range(3):
        eng.tick()
    steps_before = eng.stats["decode_steps"]
    assert eng.slots[0].request is not None and steps_before >= 2
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=2)
    served, _ = eng.tick()               # admission happens this tick...
    assert eng.slots[1].request is not None, "no mid-decode swap-in"
    assert eng.stats["batches"] == 1     # same busy period, no new wave
    while eng.stats["served"] < 2:       # ...and both run to completion
        eng.tick()
    # The short request overtakes the long one — the point of slot swap.
    lens = [len(eng.get_response(0, 10).tokens_out) for _ in range(2)]
    assert lens == [2, 10], lens
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_wave_scheduler_still_available(engine_setup):
    """The wave baseline behind scheduler='wave' still serves correctly
    (it is the A/B baseline for benchmarks/bench_serve.py)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=4, max_len=32, n_clients=2,
                      scheduler="wave")
    for c in range(2):
        assert eng.submit(c, np.arange(4) % cfg.vocab_size,
                          max_tokens=3) is not None
    assert eng.step() == 2
    assert eng.stats["batches"] == 1
    for c in range(2):
        resp = eng.get_response(c, timeout_s=10)
        assert resp is not None and len(resp.tokens_out) == 3


# ---------------------------------------------------------------------------
# streaming session API (handles, per-token delivery, cancel)
# ---------------------------------------------------------------------------
def test_token_event_wire_format_roundtrip():
    for rid, pos, tok in [(0, 0, 0), (7, 3, 121), (65535, 511, 2**31 - 1),
                          (65536, 0, 5)]:             # req_id wraps mod 2^16
        ev = pack_token_event(rid, pos, tok)
        assert isinstance(ev, int)                    # one scalar per step
        assert unpack_token_event(ev) == (rid & 0xFFFF, pos, tok)


def test_streaming_tokens_as_produced(engine_setup):
    """RequestHandle.tokens() delivers every output position exactly once,
    in order, and interleaves with decode (tokens arrive before the
    request is terminal when the engine runs concurrently)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    eng_thread = eng.start()
    try:
        session = eng.connect(0)
        h = session.submit_i(np.arange(5) % cfg.vocab_size, max_tokens=8)
        got = list(h.tokens(timeout_s=60))
        assert [p for p, _ in got] == list(range(8))
        final = h.response
        assert final is not None
        assert final.fsm.state == states.REQUEST_COMPLETED
        assert [t for _, t in got] == list(final.tokens_out)
        assert final.first_token_t >= final.submit_t
        assert len(final.token_ts) == 8
    finally:
        eng.stop()
        eng_thread.join(timeout=10)
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_streaming_two_interleaved_requests_demux(engine_setup):
    """Two in-flight requests on one session: the pump demultiplexes the
    shared stream ring by req_id; both handles see their own tokens."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    session = eng.connect(0)
    h1 = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=6)
    h2 = session.submit_i(np.arange(6) % cfg.vocab_size, max_tokens=3)
    eng.step()                          # drive both to completion inline
    r1, r2 = h1.wait(timeout_s=10), h2.wait(timeout_s=10)
    assert r1 and r2
    assert [t for _, t in h1.tokens(timeout_s=10)] == list(r1.tokens_out)
    assert [t for _, t in h2.tokens(timeout_s=10)] == list(r2.tokens_out)
    assert len(r1.tokens_out) == 6 and len(r2.tokens_out) == 3


def test_cancel_mid_decode_frees_kv_and_keeps_batcher_alive(engine_setup):
    """The acceptance property: cancel() mid-decode frees the slot's KV
    pages (pool stats return to baseline) without wedging the batcher."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    baseline = _occupancy(eng.pool)
    session = eng.connect(0)
    h = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=40)
    for _ in range(4):
        eng.tick()                      # request is mid-decode
    assert eng.slots[0].request is not None
    assert eng.pool.used_pages() > 0
    assert h.cancel() is True
    assert h.cancel() is False          # exactly one winning proposal
    eng.tick()                          # abort sweep runs this tick
    assert _occupancy(eng.pool) == baseline, "KV pages not returned"
    assert eng.stats["cancelled"] == 1
    r = h.wait(timeout_s=10)
    assert r.fsm.state == states.REQUEST_CANCELLED
    assert 0 < len(r.tokens_out) < 40   # partial output delivered
    # the batcher is not wedged: the next request runs to completion
    h2 = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=3)
    eng.step()
    r2 = h2.wait(timeout_s=10)
    assert r2 and r2.fsm.state == states.REQUEST_COMPLETED
    assert _occupancy(eng.pool) == baseline
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE


def test_cancel_while_queued_never_touches_a_slot(engine_setup):
    """cancel() before the batcher admits: the intake pop observes the
    lost CAS, no pages are claimed, the terminal is CANCELLED/empty."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=32, n_clients=1,
                      pool_pages=256, scheduler="slot")
    session = eng.connect(0)
    h = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=4)
    assert h.submitted
    assert h.cancel() is True           # engine has not seen it yet
    eng.step()
    r = h.wait(timeout_s=10)
    assert r.fsm.state == states.REQUEST_CANCELLED
    assert len(r.tokens_out) == 0
    assert eng.stats["cancelled"] == 1 and eng.stats["served"] == 0
    assert eng.stats["prefills"] == 0   # never reached a slot
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_cancel_vs_completion_race_is_single_terminal(engine_setup):
    """Client cancels at a random moment while the engine thread decodes:
    whatever interleaving happens, the request lands in exactly one
    terminal state, pages return to baseline, nothing deadlocks."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot")
    eng_thread = eng.start()
    try:
        session = eng.connect(0)
        for i in range(6):
            h = session.submit_i(np.arange(4) % cfg.vocab_size,
                                 max_tokens=12)
            canceller = threading.Timer(0.002 * i, h.cancel)
            canceller.start()
            r = h.wait(timeout_s=60)
            canceller.join()
            assert r, "handle wait timed out"
            assert r.fsm.state in (states.REQUEST_COMPLETED,
                                   states.REQUEST_CANCELLED)
    finally:
        eng.stop()
        eng_thread.join(timeout=10)
    assert eng.pool.free_pages() == eng.pool.n_pages
    assert (eng.stats["served"] + eng.stats["cancelled"]
            + eng.stats["rejected"]) == 6


def test_get_response_timeout_is_typed(engine_setup):
    """The timeout path returns a falsy TimeoutStatus carrying the last
    Table-1 status — not a bare raise, not an untyped None."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=32, n_clients=1)
    resp = eng.get_response(0, timeout_s=0.05)
    assert isinstance(resp, TimeoutStatus)
    assert not resp                     # falsy: `if not resp:` just works
    assert resp.waited_s == 0.05
    # after a real response the same call returns the Request
    assert eng.submit(0, np.arange(3) % cfg.vocab_size, max_tokens=2)
    eng.step()
    assert eng.get_response(0, timeout_s=10).fsm.state == \
        states.REQUEST_COMPLETED


def test_legacy_submit_is_a_session_wrapper(engine_setup):
    """submit()/get_response() still behave exactly as before, layered
    over Session.submit_i + detach (the blocking-over-handles rule)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1)
    req = eng.submit(0, np.arange(5) % cfg.vocab_size, max_tokens=4)
    assert req is not None and req.fsm.state == states.REQUEST_VALID
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert resp is req                  # same object comes back
    assert resp.tokens_out.shape == (4,)


def test_submit_i_pending_on_full_intake_then_recovers(engine_setup):
    """A full intake ring leaves the submission handle PENDING instead of
    dropping it; the handle's own polling delivers it once the batcher
    drains, and the request still completes."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=32, n_clients=1,
                      pool_pages=256, intake_depth=2, scheduler="slot")
    session = eng.connect(0)
    hs = [session.submit_i(np.arange(3) % cfg.vocab_size, max_tokens=2)
          for _ in range(3)]
    assert [h.submitted for h in hs] == [True, True, False]
    eng.step()                          # drains the ring; slot serves all
    # polling the pending handle pushes the send through; engine thread
    # is inline here, so alternate pump and step
    for _ in range(20):
        if hs[2].test():
            break
        eng.step()
    rs = [h.wait(timeout_s=10) for h in hs]
    assert all(r and r.fsm.state == states.REQUEST_COMPLETED for r in rs)
    assert eng.stats["served"] == 3


# ---------------------------------------------------------------------------
# packet-mode fused decode (scheduler="slot_fused", the default)
# ---------------------------------------------------------------------------
def _run_workload(model, params, scheduler, lengths, vocab, eos_id=-1,
                  **engine_kw):
    """Serve a fixed workload; returns (engine, per-request sequences in
    submission order)."""
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler=scheduler, **engine_kw)
    rids = []
    for i, n in enumerate(lengths):
        r = eng.submit(0, (np.arange(4) + i) % vocab, max_tokens=n,
                       eos_id=eos_id)
        assert r is not None
        rids.append(r.req_id)
    while eng.stats["served"] + eng.stats["rejected"] < len(lengths):
        eng.step()
    got = {}
    for _ in range(len(lengths)):
        r = eng.get_response(0, timeout_s=10)
        assert r, "response timed out"
        got[r.req_id] = list(map(int, r.tokens_out))
    return eng, [got[r] for r in rids]


def test_fused_equals_unfused_token_sequences(engine_setup):
    """The acceptance property: for a fixed seed the fused block decoder
    produces exactly the token sequences of the per-token slot path —
    packet mode changes the exchange granularity, never the tokens."""
    cfg, model, params = engine_setup
    lengths = [12, 2, 7, 2, 1, 9, 24, 3]    # mixed, forces adaptive K
    e_slot, s_slot = _run_workload(model, params, "slot", lengths,
                                   cfg.vocab_size)
    e_fused, s_fused = _run_workload(model, params, "slot_fused", lengths,
                                     cfg.vocab_size)
    assert s_fused == s_slot
    assert [len(s) for s in s_slot] == lengths
    assert e_fused.pool.free_pages() == e_fused.pool.n_pages
    # and the point of the exercise: fewer host syncs for the same tokens
    toks = sum(lengths)
    assert e_fused.stats["host_syncs"] < e_slot.stats["host_syncs"]
    assert e_fused.stats["fused_blocks"] > 0
    assert e_slot.stats["fused_blocks"] == 0
    # every non-prefill token is exactly one busy row-step of a block
    assert e_fused.stats["slot_busy_steps"] == toks - len(lengths)


def test_fused_eos_masking_matches_scalar(engine_setup):
    """Per-row EOS masking inside the fused block: rows that emit their
    stop token mid-block stop exactly where the scalar path stops."""
    cfg, model, params = engine_setup
    # discover the greedy token stream, then use its value as EOS
    _, seqs = _run_workload(model, params, "slot_fused", [6], cfg.vocab_size)
    eos = seqs[0][0]
    e_slot, s_slot = _run_workload(model, params, "slot", [6, 17],
                                   cfg.vocab_size, eos_id=eos)
    e_fused, s_fused = _run_workload(model, params, "slot_fused", [6, 17],
                                     cfg.vocab_size, eos_id=eos)
    assert s_fused == s_slot
    assert all(s[-1] == eos or len(s) in (6, 17) for s in s_fused)


def test_fused_block_amortizes_syncs_and_ring_ops(engine_setup):
    """A saturated pool of long generations decodes in K>=3 blocks: host
    syncs and stream-ring operations per token drop well below 1."""
    cfg, model, params = engine_setup
    eng, seqs = _run_workload(model, params, "slot_fused", [24, 24, 24, 24],
                              cfg.vocab_size)
    toks = sum(len(s) for s in seqs)
    assert toks == 96
    assert eng.stats["host_syncs"] / toks <= 0.35, eng.stats
    assert eng.stats["ring_ops"] / toks < 1.0, eng.stats
    assert eng.occupancy() > 0.5


def test_fused_streaming_delivers_every_position_once(engine_setup):
    """tokens() over the burst-filled stream ring: every output position
    exactly once, in order, with per-token timestamps covering the whole
    generation (interpolated inside blocks, exact at the first token)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256)          # slot_fused is the default
    assert eng.scheduler == "slot_fused"
    eng_thread = eng.start()
    try:
        h = eng.connect(0).submit_i(np.arange(5) % cfg.vocab_size,
                                    max_tokens=11)
        got = list(h.tokens(timeout_s=60))
        final = h.response
        assert [p for p, _ in got] == list(range(11))
        assert [t for _, t in got] == list(final.tokens_out)
        assert final.first_token_t >= final.submit_t
        assert len(final.token_ts) == 11
        assert final.token_ts == sorted(final.token_ts)   # monotone ITL
    finally:
        eng.stop()
        eng_thread.join(timeout=10)
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_fused_cancel_mid_decode_bounded_by_one_block(engine_setup):
    """cancel() against the fused batcher: the abort sweep runs at the
    next block boundary, KV pages return to baseline, and the batcher
    keeps serving."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot_fused")
    baseline = _occupancy(eng.pool)
    session = eng.connect(0)
    h = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=40)
    for _ in range(3):
        eng.tick()                      # request is mid-generation
    assert eng.slots[0].request is not None
    assert h.cancel() is True
    eng.tick()                          # abort sweep: next block boundary
    assert _occupancy(eng.pool) == baseline, "KV pages not returned"
    r = h.wait(timeout_s=10)
    assert r.fsm.state == states.REQUEST_CANCELLED
    assert 0 < len(r.tokens_out) < 40
    h2 = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=3)
    eng.step()
    r2 = h2.wait(timeout_s=10)
    assert r2 and r2.fsm.state == states.REQUEST_COMPLETED
    assert _occupancy(eng.pool) == baseline


def test_note_tokens_per_block_matches_per_step():
    """Regression for block-batched page accounting: one idempotent
    note_tokens(seq, final) call per block leaves the pool in exactly
    the state the per-step path produced."""
    def drive(step_sizes):
        pool = PagedKVPool(16, page_size=4, n_layers=2, kv_heads=2,
                           head_dim=8)
        assert pool.try_admit(7, 20, slot=3) == OK
        n = 4                                   # prompt tokens
        pool.note_tokens(7, n)
        for k in step_sizes:
            n += k
            pool.note_tokens(7, n)              # one call per "block"
        return pool.stats(), n

    per_step, n1 = drive([1] * 12)              # the scalar path
    per_block, n2 = drive([2, 8, 1, 1])         # fused blocks, same total
    assert n1 == n2 == 16
    assert per_step == per_block
    assert per_step["per_slot"][3] == (5, 16, 20)   # pages, tokens, reserved


# ---------------------------------------------------------------------------
# chunked zero-copy admission (scheduler="slot_chunked", DESIGN.md §9)
# ---------------------------------------------------------------------------
def test_chunked_equals_slot_across_chunk_sizes(engine_setup):
    """The acceptance property: for every chunk size (1, 4, and a whole
    bucketed prompt) the chunked scheduler emits token sequences
    byte-identical to the scalar slot path AND the fused path — in-place
    chunk admission changes the exchange plan, never the tokens."""
    cfg, model, params = engine_setup
    lengths = [12, 2, 7, 2, 1, 9, 24, 3]     # mixed, forces adaptive K
    e_slot, s_slot = _run_workload(model, params, "slot", lengths,
                                   cfg.vocab_size)
    _, s_fused = _run_workload(model, params, "slot_fused", lengths,
                               cfg.vocab_size)
    assert s_fused == s_slot
    for chunk in (1, 4, 8):                  # prompts bucket to 8
        e_c, s_c = _run_workload(model, params, "slot_chunked", lengths,
                                 cfg.vocab_size, chunk_tokens=chunk)
        assert s_c == s_slot, f"chunk_tokens={chunk} diverged"
        # Zero-copy: no B=1 side cache was ever copied into the batch
        # cache, and no dedicated per-admission sync was paid.
        assert e_c.stats["cache_copy_dispatches"] == 0
        assert e_c.stats["admission_stall_steps"] == 0
        assert e_c.pool.free_pages() == e_c.pool.n_pages
        # Dispatches carrying prefill work are bounded by the chunk
        # count: sum over admissions of ceil(padded / chunk).
        bound = sum(-(-8 // chunk) for _ in lengths)
        assert e_c.stats["prefill_dispatches"] <= bound
        assert e_c.stats["prefill_chunks"] == bound
    # The monolithic paths pay a copy dispatch and stall active slots.
    assert e_slot.stats["cache_copy_dispatches"] == len(lengths)
    assert e_slot.stats["admission_stall_steps"] > 0


def test_chunked_equivalence_when_padded_tail_wraps_ring(engine_setup):
    """Regression: a final chunk whose PADDED tail pushes start + chunk
    past the cache ring size must not bump the wrap epoch — validity and
    slot positions are computed from the true valid extent, so the
    chunk's queries still see the whole prompt."""
    cfg, model, params = engine_setup
    def serve(scheduler, **kw):
        eng = ServeEngine(model, params, max_batch=2, max_len=96,
                          n_clients=1, pool_pages=512,
                          scheduler=scheduler, **kw)
        rids = []
        for i, (plen, mt) in enumerate([(48, 4), (4, 8)]):
            r = eng.submit(0, (np.arange(plen) + i) % cfg.vocab_size,
                           max_tokens=mt)
            rids.append(r.req_id)
        while eng.stats["served"] < 2:
            eng.step()
        got = {}
        for _ in range(2):
            r = eng.get_response(0, timeout_s=10)
            got[r.req_id] = list(map(int, r.tokens_out))
        return [got[r] for r in rids]

    base = serve("slot")
    # bucket(48) = 64; the final chunk starts at 50, and 50 + 50 > 96.
    assert serve("slot_chunked", chunk_tokens=50) == base
    assert serve("slot_chunked", chunk_tokens=96) == base


def test_wave_oversize_check_uses_raw_prompt_len(engine_setup):
    """Regression: the fail-fast footprint must not bucket for the wave
    scheduler — bucket(17)=32 would reject a 17-token prompt that wave
    (which pads only to the batch max) serves in full."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1,
                      scheduler="wave")
    req = eng.submit(0, np.arange(17) % cfg.vocab_size, max_tokens=8)
    assert req is not None and req.fsm.state == states.REQUEST_VALID
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert resp.fsm.state == states.REQUEST_COMPLETED
    assert len(resp.tokens_out) == 8
    assert len(eng.oversize_log) == 0


def test_chunked_eos_masking_matches_scalar(engine_setup):
    """A row that joins the decode block in the same dispatch as its
    final chunk still stops exactly at EOS (the scan's initial liveness
    mask sees the on-device prefill token)."""
    cfg, model, params = engine_setup
    _, seqs = _run_workload(model, params, "slot_chunked", [6],
                            cfg.vocab_size, chunk_tokens=4)
    eos = seqs[0][0]
    _, s_slot = _run_workload(model, params, "slot", [6, 17],
                              cfg.vocab_size, eos_id=eos)
    _, s_c = _run_workload(model, params, "slot_chunked", [6, 17],
                           cfg.vocab_size, eos_id=eos, chunk_tokens=4)
    assert s_c == s_slot


def test_chunked_long_prompt_does_not_stall_decode(engine_setup):
    """The interference property: while a long prompt streams in chunk
    by chunk, the already-active slot keeps decoding — at least one
    decode step lands in every chunk-carrying dispatch, and the stall
    counter stays at zero (the fused path stalls the active slot once
    per admission)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=128, n_clients=1,
                      pool_pages=256, scheduler="slot_chunked",
                      chunk_tokens=4)
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=60)
    while not any(s.generated > 0 for s in eng.slots):
        eng.tick()
    eng.submit(0, np.arange(33) % cfg.vocab_size, max_tokens=4)  # bucket 64
    eng.tick()                      # admission sweep binds the slot
    streamer = [s for s in eng.slots
                if s.request is not None and s.request.max_tokens == 4]
    assert streamer and streamer[0].prefill_pos > 0, "not streaming"
    active = [s for s in eng.slots
              if s.request is not None and s.request.max_tokens == 60][0]
    chunk_ticks = 0
    while streamer[0].generated == 0 and streamer[0].request is not None:
        before = active.generated
        eng.tick()
        chunk_ticks += 1
        assert active.generated >= before + 1, \
            "active slot stalled during a prefill chunk"
    assert chunk_ticks >= 10            # 64-token bucket in 4-token chunks
    assert eng.stats["admission_stall_steps"] == 0
    while eng.stats["served"] < 2:
        eng.tick()
    got = sorted(len(eng.get_response(0, 10).tokens_out) for _ in range(2))
    assert got == [4, 60]
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_chunked_batched_multi_slot_admission_sweep(engine_setup):
    """A burst of arrivals from idle is drained into ALL free slots
    before the first dispatch: their first chunks share ONE device
    dispatch and ONE host sync, and the burst costs one busy-period
    stats bump, not one per request."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=4, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot_chunked",
                      chunk_tokens=8)
    for i in range(4):
        assert eng.submit(0, (np.arange(4) + i) % cfg.vocab_size,
                          max_tokens=3) is not None
    eng.tick()
    assert eng.stats["admitted"] == 4
    assert eng.stats["batches"] == 1
    assert eng.stats["prefill_dispatches"] == 1     # 4 admissions, 1 dispatch
    assert eng.stats["prefill_chunks"] == 4
    assert eng.stats["host_syncs"] == 1
    while eng.stats["served"] < 4:
        eng.tick()
    for _ in range(4):
        assert len(eng.get_response(0, 10).tokens_out) == 3
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_chunked_page_accounting_per_chunk(engine_setup):
    """Pages are claimed chunk by chunk as positions materialize: after
    every streaming tick the sequence holds exactly
    ``pages_needed(extent)`` pages, and the decode budget is reserved
    with the final chunk."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=1, max_len=128, n_clients=1,
                      pool_pages=64, page_size=4, scheduler="slot_chunked",
                      chunk_tokens=4)
    req = eng.submit(0, np.arange(9) % cfg.vocab_size, max_tokens=20)
    padded = 16                                     # bucket of 9
    extents = []
    while eng.slots[0].generated == 0:
        eng.tick()
        assert eng.slots[0].request is not None
        t = eng.pool.table(req.req_id)
        extents.append((eng.slots[0].prefill_pos, len(t.pages),
                        t.n_reserved))
    mid = [e for e in extents if e[0] < padded]
    assert len(mid) == 3, extents                   # 16 tokens, 4-chunks
    for extent, pages, reserved in mid:
        assert pages == eng.pool.pages_needed(extent)
        assert reserved == max(4, extent)           # first-chunk floor
    final = [e for e in extents if e[0] == padded]
    assert final
    assert final[0][1] == eng.pool.pages_needed(padded + 20)
    assert final[0][2] == padded + 20               # decode budget reserved
    while eng.stats["served"] < 1:
        eng.tick()
    assert len(eng.get_response(0, 10).tokens_out) == 20
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_extend_reservation_matches_upfront_admission():
    """Chunk-boundary page accounting against note_tokens: claiming the
    reservation incrementally (extend_reservation per chunk + one
    note_tokens per block) lands the pool in exactly the state the
    all-upfront try_admit + per-step note_tokens path produced."""
    def upfront():
        pool = PagedKVPool(32, page_size=4, n_layers=2, kv_heads=2,
                           head_dim=8)
        assert pool.try_admit(9, 24, slot=1) == OK  # 16 prompt + 8 decode
        n = 16
        pool.note_tokens(9, n)
        for _ in range(8):
            n += 1
            pool.note_tokens(9, n)
        return pool.stats(), n

    def chunked():
        pool = PagedKVPool(32, page_size=4, n_layers=2, kv_heads=2,
                           head_dim=8)
        assert pool.try_admit(9, 4, slot=1) == OK   # first chunk only
        for extent in (4, 8, 12):
            assert pool.extend_reservation(9, extent) == OK
            pool.note_tokens(9, extent)
        assert pool.extend_reservation(9, 24) == OK  # final chunk
        pool.note_tokens(9, 17)                      # prompt + first token
        for n in (19, 23, 24):                       # fused decode blocks
            pool.note_tokens(9, n)
        return pool.stats(), 24

    a, n1 = upfront()
    b, n2 = chunked()
    assert n1 == n2 and a == b
    assert a["per_slot"][1] == (6, 24, 24)      # pages, tokens, reserved


def test_extend_reservation_rolls_back_on_pool_full():
    pool = PagedKVPool(4, page_size=4, n_layers=1, kv_heads=1, head_dim=2)
    assert pool.try_admit(1, 4) == OK               # 1 page
    assert pool.try_admit(2, 8) == OK               # 2 pages; 1 free
    assert pool.extend_reservation(1, 24) == POOL_FULL  # needs 5 more
    assert pool.free_pages() == 1                   # all-or-nothing
    assert len(pool.table(1).pages) == 1
    assert pool.extend_reservation(1, 8) == OK      # the last page fits
    assert pool.free_pages() == 0


def test_chunked_cancel_mid_stream_releases_reserved_slot(engine_setup):
    """cancel() while the prompt is still streaming: the RESERVED slot
    takes the direct RESERVED->FREE edge, pages return, the terminal is
    CANCELLED/empty, and the batcher keeps serving."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=128, n_clients=1,
                      pool_pages=256, scheduler="slot_chunked",
                      chunk_tokens=4)
    baseline = _occupancy(eng.pool)
    session = eng.connect(0)
    h1 = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=20)
    for _ in range(3):
        eng.tick()
    h2 = session.submit_i(np.arange(40) % cfg.vocab_size, max_tokens=8)
    eng.tick()
    eng.tick()
    mid = [s for s in eng.slots
           if s.request is not None and s.generated == 0]
    assert mid and 0 < mid[0].prefill_pos < len(mid[0].prompt)
    assert h2.cancel() is True
    eng.tick()                          # abort sweep releases RESERVED slot
    r2 = h2.wait(timeout_s=10)
    assert r2.fsm.state == states.REQUEST_CANCELLED
    assert len(r2.tokens_out) == 0
    while eng.stats["served"] < 1:
        eng.tick()
    r1 = h1.wait(timeout_s=10)
    assert len(r1.tokens_out) == 20
    assert _occupancy(eng.pool) == baseline
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE


def test_chunked_mid_stream_pool_exhaustion_rejects(engine_setup):
    """A long prompt that outgrows the pool mid-stream is rejected whole
    (all-or-nothing): pages roll back, the slot frees, the terminal is
    the standard rejection."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=128, n_clients=1,
                      pool_pages=4, page_size=4,   # 16 tokens of KV total
                      scheduler="slot_chunked", chunk_tokens=4)
    eng.submit(0, np.arange(30) % cfg.vocab_size, max_tokens=8)  # bucket 32
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert resp.fsm.state == states.REQUEST_CANCELLED
    assert eng.stats["rejected"] == 1
    assert eng.pool.free_pages() == eng.pool.n_pages
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE
    # the batcher is not wedged
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=2)
    eng.step()
    assert eng.get_response(0, 10).fsm.state == states.REQUEST_COMPLETED


def test_chunked_streaming_tokens_and_ttft(engine_setup):
    """The streaming surface over the chunked scheduler: every position
    exactly once, first_token_t set at the final chunk's harvest, and
    monotone per-token timestamps."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot_chunked",
                      chunk_tokens=4)
    eng_thread = eng.start()
    try:
        h = eng.connect(0).submit_i(np.arange(5) % cfg.vocab_size,
                                    max_tokens=11)
        got = list(h.tokens(timeout_s=60))
        final = h.response
        assert [p for p, _ in got] == list(range(11))
        assert [t for _, t in got] == list(final.tokens_out)
        assert final.first_token_t >= final.submit_t
        assert len(final.token_ts) == 11
        assert final.token_ts == sorted(final.token_ts)
    finally:
        eng.stop()
        eng_thread.join(timeout=10)
    assert eng.pool.free_pages() == eng.pool.n_pages


# ---------------------------------------------------------------------------
# fail-fast oversize rejection at the session layer
# ---------------------------------------------------------------------------
def test_submit_oversized_fails_fast_with_typed_status(engine_setup):
    """A request whose footprint can never fit max_len is refused at
    submit_i time: terminal handle, typed falsy OversizeStatus, no
    intake round-trip, no batcher work, no pages touched."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1)
    session = eng.connect(0)
    h = session.submit_i(np.arange(20) % cfg.vocab_size, max_tokens=16)
    assert h.done and not h.submitted
    assert isinstance(h.status, OversizeStatus) and not h.status
    assert h.status.padded_len == 32 and h.status.max_len == 32
    assert h.response.fsm.state == states.REQUEST_CANCELLED
    assert list(h.tokens()) == []
    assert h.wait(timeout_s=1) is h.response
    assert h.cancel() is False          # already terminal
    # no engine-side traffic of any kind
    assert len(eng.oversize_log) == 1
    assert eng.stats["admitted"] == 0 and eng.stats["prefills"] == 0
    assert eng.pool.free_pages() == eng.pool.n_pages
    _, worked = eng.tick()
    assert not worked                   # the batcher never saw it


def test_submit_oversized_legacy_surface(engine_setup):
    """The legacy submit()/get_response() pair still delivers exactly
    one terminal for an oversized request (routed locally, no ring)."""
    cfg, model, params = engine_setup
    for scheduler in ("slot_chunked", "slot_fused", "wave"):
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          n_clients=1, scheduler=scheduler)
        req = eng.submit(0, np.arange(30) % cfg.vocab_size, max_tokens=8)
        assert req is not None
        assert req.fsm.state == states.REQUEST_CANCELLED
        resp = eng.get_response(0, timeout_s=5)
        assert resp is req
        assert len(resp.tokens_out) == 0
        # an in-range request still completes afterwards
        assert eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=2)
        eng.step()
        assert eng.get_response(0, 10).fsm.state == states.REQUEST_COMPLETED


def test_engine_threaded_clients(engine_setup):
    """Concurrent client threads + engine thread: all requests complete."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=4, max_len=32, n_clients=4,
                      pool_pages=256)
    eng_thread = eng.start()
    n_per_client = 3
    got = {c: [] for c in range(4)}

    def client(c):
        import time
        sent = 0
        while sent < n_per_client:
            if eng.submit(c, (np.arange(4) + c) % cfg.vocab_size,
                          max_tokens=2) is not None:
                sent += 1
            else:
                time.sleep(0.001)
        while len(got[c]) < n_per_client:
            r = eng.get_response(c, timeout_s=30)
            assert r, f"client {c} timed out: {r}"
            got[c].append(r)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    eng.stop()
    eng_thread.join(timeout=10)
    assert all(len(v) == n_per_client for v in got.values())
    assert eng.stats["served"] == 12
    assert eng.pool.free_pages() == eng.pool.n_pages
