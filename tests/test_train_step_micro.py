"""Gradient accumulation: microbatched step == monolithic step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.train.optimizer import AdamW, OptConfig
from repro.train.train_step import make_train_step

jax.config.update("jax_platform_name", "cpu")


def test_microbatched_matches_monolithic():
    import dataclasses
    cfg = get_smoke_config("smollm-135m")
    # f32 params so the comparison isn't dominated by bf16 rounding
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(OptConfig(lr=1e-3, clip_norm=1e9))  # no clip interference
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}

    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    p1, o1, m1 = s1(params, opt.init(params), batch)
    p4, o4, m4 = s4(params, opt.init(params), batch)

    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)
