"""Examples must stay runnable (subprocess smoke with tiny settings)."""
import os
import subprocess
import sys
from pathlib import Path


ROOT = Path(__file__).resolve().parents[1]


def _run(script, *args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return proc.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "train step: loss=" in out
    assert "generated token ids" in out


def test_quickstart_moe_arch():
    out = _run("quickstart.py", "--arch", "olmoe-1b-7b")
    assert "generated token ids" in out


def test_train_e2e_short(tmp_path):
    # enough steps to clear the 20-step LR warmup so loss visibly drops
    out = _run("train_e2e.py", "--steps", "35", "--batch", "2",
               "--seq", "32", "--ckpt-dir", str(tmp_path), timeout=600)
    assert "loss:" in out and "checkpoints:" in out


def test_lockfree_pipeline_demo():
    out = _run("lockfree_pipeline_demo.py")
    rows = {}
    for line in out.splitlines():
        parts = line.split()
        if (len(parts) >= 4 and parts[0] in ("barrier", "nbb", "nbb2")
                and parts[1].replace(",", "").isdigit()):
            rows[parts[0]] = parts
    assert rows["barrier"][3] == "True" and rows["nbb"][3] == "True"
    b = int(rows["barrier"][1].replace(",", ""))
    n = int(rows["nbb"][1].replace(",", ""))
    assert b >= 4 * n   # ring moves ~1/S of the barrier's bytes
