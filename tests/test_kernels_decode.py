"""Kernel coverage at serving shapes: single-query decode and ragged
GQA group sizes; plus VMEM-budget sanity for the production tiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

jax.config.update("jax_platform_name", "cpu")


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_decode_single_query():
    """T=1 against a long KV history — the serve_step shape."""
    B, S, H, Hkv, hd = 2, 512, 8, 2, 64
    q = rand(0, (B, 1, H, hd))
    k = rand(1, (B, S, Hkv, hd))
    v = rand(2, (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_with_window():
    B, S, H, hd = 1, 1024, 4, 64
    q = rand(3, (B, 1, H, hd))
    k = rand(4, (B, S, H, hd))
    v = rand(5, (B, S, H, hd))
    out = flash_attention(q, k, v, causal=True, window=256, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("H,Hkv", [(16, 1), (12, 4), (9, 3)])
def test_gqa_group_sizes(H, Hkv):
    """MQA (g=16), odd groups (g=3) — the zoo's head configs."""
    B, T, hd = 1, 128, 64
    q = rand(6, (B, T, H, hd))
    k = rand(7, (B, T, Hkv, hd))
    v = rand(8, (B, T, Hkv, hd))
    out = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_production_tile_fits_vmem():
    """BlockSpec working set must fit 16 MB VMEM at the 32k-prefill tile."""
    bq, bk, hd = 128, 128, 128
    # q tile + k tile + v tile (bf16 inputs) + f32 scratch (acc, m, l)
    working = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4
    assert working < 16 * 1024 * 1024
