"""Replay the tests/schedules/ corpus (DESIGN.md §15).

Every JSON file is a schedule the checker once found interesting — a
minimized counterexample against a preserved-broken implementation
(``expect: violation``) or a regression schedule that once exposed a
since-fixed bug and must now pass (``expect: pass``).  Replaying them
is cheap (one execution each) and pins both the scenarios' shapes and
the fixes themselves.
"""
import glob
import os

import pytest

from repro.core import interleave as il
from repro.checker import scenarios

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "schedules")
CORPUS = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json")))


def test_corpus_exists():
    assert CORPUS, "tests/schedules/ corpus is empty"


@pytest.mark.parametrize(
    "path", CORPUS, ids=[os.path.basename(p) for p in CORPUS])
def test_replay(path):
    rec = il.load_schedule(path)
    scen = scenarios.get(rec["scenario"])
    res = il.run_schedule(scen.make_world, rec["schedule"],
                          max_steps=scen.max_steps, strict=False)
    if rec["expect"] == "violation":
        assert res.failed, (
            f"{path}: schedule no longer reproduces the violation "
            f"(did the scenario change shape?)")
    else:
        assert not res.failed, (
            f"{path}: regression schedule fails again: {res.error!r}\n"
            f"note: {rec.get('note', '')}")


def test_corpus_scenarios_registered():
    for path in CORPUS:
        rec = il.load_schedule(path)
        assert rec["scenario"] in scenarios.SCENARIOS, path
