"""Overload-control subsystem tests (DESIGN.md §12): priority intake,
page-swap preemption, WFQ, aging, and SLO shedding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import nbb, states
from repro.models.model import build_model
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import OK, POOL_FULL, PagedKVPool
from repro.serve.overload import (PRIORITY_HIGH, PRIORITY_LOW,
                                  PRIORITY_NORMAL, OverloadPolicy,
                                  PriorityIntake, ShedStatus)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except Exception:                                   # pragma: no cover
    st = None

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# PriorityIntake units (no model)
# ---------------------------------------------------------------------------
def test_intake_strict_priority_order():
    q = PriorityIntake(1, OverloadPolicy(wfq=False))
    for item, pri in [("l1", PRIORITY_LOW), ("n1", PRIORITY_NORMAL),
                      ("h1", PRIORITY_HIGH), ("h2", PRIORITY_HIGH)]:
        assert q.producer(0, pri).insert_item(item) == nbb.OK
    got = [q.pop()[1] for _ in range(4)]
    assert got == ["h1", "h2", "n1", "l1"]      # classes first, FIFO within
    assert q.pop() == (nbb.BUFFER_EMPTY, None, False)


def test_intake_aging_promotes_starved_class():
    q = PriorityIntake(1, OverloadPolicy(wfq=False, aging_limit=2))
    assert q.producer(0, PRIORITY_LOW).insert_item("low") == nbb.OK
    ring_h = q.producer(0, PRIORITY_HIGH)
    order = []
    for i in range(6):
        assert ring_h.insert_item(f"h{i}") == nbb.OK
    for _ in range(7):
        status, item, promoted = q.pop()
        assert status == nbb.OK
        order.append((item, promoted))
    # low is bypassed aging_limit=2 times, then served next — promoted.
    assert order[0] == ("h0", False) and order[1] == ("h1", False)
    assert order[2] == ("low", True)
    assert [it for it, _ in order[3:]] == ["h2", "h3", "h4", "h5"]


def test_intake_wfq_interleaves_flooding_client():
    q = PriorityIntake(2, OverloadPolicy())
    for i in range(6):
        assert q.producer(0, PRIORITY_NORMAL).insert_item(("a", i)) == nbb.OK
    for i in range(2):
        assert q.producer(1, PRIORITY_NORMAL).insert_item(("b", i)) == nbb.OK
    got = []
    for _ in range(8):
        status, (cid, i), _ = q.pop()
        assert status == nbb.OK
        got.append(cid)
        q.charge(0 if cid == "a" else 1, 10.0)  # equal cost per pop
    # equal weights: client b's two items are served within the first
    # four pops instead of waiting behind client a's entire burst.
    assert got[:4].count("b") == 2
    assert got.count("a") == 6 and got.count("b") == 2


def test_intake_wfq_weights_bias_service():
    q = PriorityIntake(2, OverloadPolicy(weights=(3.0, 1.0)))
    for i in range(6):
        q.producer(0, PRIORITY_NORMAL).insert_item(("a", i))
        q.producer(1, PRIORITY_NORMAL).insert_item(("b", i))
    got = []
    for _ in range(8):
        _, (cid, _), _ = q.pop()
        got.append(cid)
        q.charge(0 if cid == "a" else 1, 12.0)
    # weight 3:1 -> client a gets ~3 pops per b pop over the window.
    assert got[:8].count("a") >= 5


def test_intake_priorities_off_single_class():
    q = PriorityIntake(3, OverloadPolicy(priorities=False), 8)
    assert q.n_classes == 1
    # any priority routes to the one class; round-robin across clients.
    q.producer(0, PRIORITY_HIGH).insert_item("x")
    q.producer(1, PRIORITY_LOW).insert_item("y")
    assert {q.pop()[1], q.pop()[1]} == {"x", "y"}


def test_policy_validation():
    with pytest.raises(ValueError):
        OverloadPolicy(n_classes=0)
    with pytest.raises(ValueError):
        OverloadPolicy(aging_limit=0)


def test_shed_status_is_falsy():
    s = ShedStatus(waited_s=1.5, slo_s=1.0, priority=PRIORITY_LOW)
    assert not s and isinstance(s, ShedStatus)


# ---------------------------------------------------------------------------
# pool: page-swap preemption
# ---------------------------------------------------------------------------
def _fill_pages(pool, pages, base):
    """Stamp identifiable values into whole pages of the pool arrays."""
    idx = jnp.asarray(pages, jnp.int32)
    shape = (len(pages),) + pool.k.shape[1:]
    kv = base + jnp.arange(np.prod(shape), dtype=pool.k.dtype).reshape(shape)
    pool.k = pool.k.at[idx].set(kv)
    pool.v = pool.v.at[idx].set(kv + 0.5)
    return np.asarray(kv), np.asarray(kv + 0.5)


def test_pool_preempt_roundtrip_byte_identical():
    pool = PagedKVPool(8, page_size=4, n_layers=2, kv_heads=2, head_dim=4,
                       dtype=jnp.float32)
    assert pool.try_admit(1, 10) == OK          # 3 pages
    want_k, want_v = _fill_pages(pool, pool.table(1).pages, 100.0)
    img = pool.swap_out_preempt(1, 10)
    assert img.rows == [0, 1, 2] and not img.dead_rows and not img.shared_rows
    assert pool.table(1).pages == [-1, -1, -1]
    assert pool.free_pages() == 8               # pages really released
    assert pool.swap_out_bytes == 3 * pool.page_nbytes
    # another sequence can take (and dirty) the freed pages meanwhile
    assert pool.try_admit(2, 16) == OK
    _fill_pages(pool, pool.table(2).pages, 900.0)
    pool.free(2)
    assert pool.swap_in_preempt(1, img) == OK
    pages = pool.table(1).pages
    assert all(p >= 0 for p in pages)
    np.testing.assert_array_equal(np.asarray(pool.k[jnp.asarray(pages)]),
                                  want_k)
    np.testing.assert_array_equal(np.asarray(pool.v[jnp.asarray(pages)]),
                                  want_v)
    assert pool.swap_in_bytes == 3 * pool.page_nbytes
    assert pool.kv_copy_bytes == pool.swap_in_bytes + pool.swap_out_bytes
    pool.free(1)
    assert pool.free_pages() == 8


def test_pool_preempt_skips_reserved_ahead_pages():
    pool = PagedKVPool(8, page_size=4, n_layers=1, kv_heads=1, head_dim=2,
                       dtype=jnp.float32)
    assert pool.try_admit(1, 20) == OK          # 5 pages reserved
    img = pool.swap_out_preempt(1, 6)           # only 2 pages live
    assert img.rows == [0, 1] and img.dead_rows == [2, 3, 4]
    # only live pages were copied; dead ones released for free
    assert pool.swap_out_bytes == 2 * pool.page_nbytes
    assert pool.free_pages() == 8
    assert pool.swap_in_preempt(1, img) == OK
    assert all(p >= 0 for p in pool.table(1).pages)
    pool.free(1)


def test_pool_preempt_never_moves_shared_pages():
    """Satellite regression: refcount>1 pages (a prefix-cache hit's
    shared prefix) stay resident through preempt/resume — never copied,
    never released, cow_copy_bytes untouched."""
    pool = PagedKVPool(8, page_size=4, n_layers=1, kv_heads=2, head_dim=4,
                       dtype=jnp.float32)
    assert pool.try_admit(1, 12) == OK          # 3 pages
    t = pool.table(1)
    shared = t.pages[0]
    pool.incref_pages([shared])                 # the cache's residency ref
    want_k = np.asarray(pool.k[shared])
    img = pool.swap_out_preempt(1, 12)
    assert img.shared_rows == [0] and img.rows == [1, 2]
    assert t.pages[0] == shared                 # row still valid, parked
    assert pool.refcount(shared) == 2           # both refs intact
    assert pool.swap_out_bytes == 2 * pool.page_nbytes
    assert pool.cow_copy_bytes == 0
    assert pool.swap_in_preempt(1, img) == OK
    assert t.pages[0] == shared                 # never moved
    np.testing.assert_array_equal(np.asarray(pool.k[shared]), want_k)
    assert pool.cow_copy_bytes == 0
    pool.free(1)                                # drops the seq's ref only
    assert pool.refcount(shared) == 1
    pool.decref_pages([shared])
    assert pool.free_pages() == 8


def test_pool_swap_in_pool_full_leaves_image_intact():
    pool = PagedKVPool(4, page_size=4, n_layers=1, kv_heads=1, head_dim=2,
                       dtype=jnp.float32)
    assert pool.try_admit(1, 8) == OK           # 2 pages
    img = pool.swap_out_preempt(1, 8)
    assert pool.try_admit(2, 16) == OK          # hog the whole pool
    assert pool.swap_in_preempt(1, img) == POOL_FULL
    assert pool.table(1).pages == [-1, -1]      # untouched, retryable
    pool.free(2)
    assert pool.swap_in_preempt(1, img) == OK
    pool.free(1)
    assert pool.free_pages() == 4


def test_pool_free_while_parked():
    """A parked (tombstoned) sequence frees cleanly — no double-release
    of pages it no longer holds."""
    pool = PagedKVPool(4, page_size=4, n_layers=1, kv_heads=1, head_dim=2)
    assert pool.try_admit(1, 8) == OK
    pool.swap_out_preempt(1, 8)
    pool.free(1)
    assert pool.free_pages() == 4 and pool.n_seqs() == 0


if st is not None:
    @settings(deadline=None, max_examples=20)
    @given(st.lists(st.integers(0, 3), min_size=1, max_size=32))
    def test_pool_preempt_resume_storm(ops):
        """Randomized admit/preempt/resume/free interleavings: pages are
        never double-freed or leaked, and every resume (and survivor)
        reads back the exact bytes written at admission."""
        pool = PagedKVPool(12, page_size=2, n_layers=1, kv_heads=1,
                           head_dim=2, dtype=jnp.float32)
        nxt = 0
        live, parked, want = {}, {}, {}
        for op in ops:
            if op == 0:                             # admit + stamp
                n_tok = 3 + (nxt % 3)
                if pool.try_admit(nxt, n_tok) == OK:
                    k, _ = _fill_pages(pool, pool.table(nxt).pages,
                                       100.0 * (nxt + 1))
                    live[nxt], want[nxt] = n_tok, k
                    nxt += 1
            elif op == 1 and live:                  # preempt oldest live
                sid = min(live)
                parked[sid] = pool.swap_out_preempt(sid, live.pop(sid))
            elif op == 2 and parked:                # resume oldest parked
                sid = min(parked)
                if pool.swap_in_preempt(sid, parked[sid]) == OK:
                    img = parked.pop(sid)
                    live[sid] = img.k.shape[0] * pool.page_size
                    pages = pool.table(sid).pages
                    np.testing.assert_array_equal(
                        np.asarray(pool.k[jnp.asarray(pages)]), want[sid])
            elif op == 3 and (live or parked):      # free newest
                sid = max(list(live) + list(parked))
                live.pop(sid, None)
                parked.pop(sid, None)
                pool.free(sid)
        for sid in live:                            # survivors unscathed
            pages = pool.table(sid).pages
            np.testing.assert_array_equal(
                np.asarray(pool.k[jnp.asarray(pages)]), want[sid])
        for sid in list(live) + list(parked):
            pool.free(sid)
        assert pool.free_pages() == pool.n_pages    # nothing leaked
        assert pool.kv_copy_bytes == (pool.swap_in_bytes
                                      + pool.swap_out_bytes)
else:                                               # pragma: no cover
    def test_pool_preempt_resume_storm():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk(model, params, overload=None, max_batch=1, pool_pages=24):
    return ServeEngine(model, params, max_batch=max_batch, max_len=64,
                       n_clients=2, pool_pages=pool_pages, page_size=8,
                       scheduler="slot_paged", k_max=4, chunk_tokens=16,
                       overload=overload)


def test_preemption_requires_paged_scheduler(engine_setup):
    _, model, params = engine_setup
    with pytest.raises(ValueError, match="slot_paged"):
        ServeEngine(model, params, scheduler="slot_chunked",
                    overload=OverloadPolicy())
    # preemption off: any scheduler takes a policy
    eng = ServeEngine(model, params, scheduler="slot_fused",
                      overload=OverloadPolicy(preemption=False))
    assert eng._ov is not None


def test_engine_preempt_resume_byte_identical(engine_setup):
    """The tentpole end-to-end: a high-priority arrival preempts the
    decoding low-priority sequence (private pages swap host-side), runs
    to completion, and the victim resumes — both token streams exactly
    equal the unpreempted runs, and every copied byte is attributed to
    swap traffic."""
    cfg, model, params = engine_setup
    low_prompt = np.arange(8) % cfg.vocab_size
    high_prompt = (np.arange(6) + 3) % cfg.vocab_size

    eng = _mk(model, params)
    h = eng.connect(0).submit_i(low_prompt, max_tokens=16)
    eng.step()
    ref_low = h.wait(timeout_s=60).tokens_out.copy()
    eng = _mk(model, params)
    h = eng.connect(1).submit_i(high_prompt, max_tokens=4)
    eng.step()
    ref_high = h.wait(timeout_s=60).tokens_out.copy()

    eng = _mk(model, params, overload=OverloadPolicy())
    hl = eng.connect(0).submit_i(low_prompt, max_tokens=16,
                                 priority=PRIORITY_LOW)
    for _ in range(3):                  # low is mid-decode ...
        eng.tick()
    hh = eng.connect(1).submit_i(high_prompt, max_tokens=4,
                                 priority=PRIORITY_HIGH)
    eng.step()                          # ... high preempts, then low resumes
    rl, rh = hl.wait(timeout_s=60), hh.wait(timeout_s=60)
    assert eng.stats["preemptions"] >= 1 and eng.stats["resumes"] >= 1
    assert rl.fsm.state == states.REQUEST_COMPLETED
    assert rh.fsm.state == states.REQUEST_COMPLETED
    np.testing.assert_array_equal(rl.tokens_out, ref_low)
    np.testing.assert_array_equal(rh.tokens_out, ref_high)
    # copied bytes are swap traffic, wholly and exactly
    pool = eng.pool
    assert pool.swap_out_bytes > 0
    assert pool.kv_copy_bytes == (pool.cow_copy_bytes + pool.swap_in_bytes
                                  + pool.swap_out_bytes)
    assert eng.stats["swap_in_bytes"] == pool.swap_in_bytes
    assert pool.free_pages() == pool.n_pages        # nothing leaked
    assert not eng._parked
    ttft = eng.class_ttft()
    assert set(ttft) == {PRIORITY_HIGH, PRIORITY_LOW}


def test_engine_preempted_slot_fsm_states(engine_setup):
    """The Figure-4 extension live: while parked the sequence's cell is
    BUFFER_PREEMPTED and the vacated slot's fresh cell binds the
    preemptor; the resume CASes PREEMPTED -> ALLOCATED."""
    cfg, model, params = engine_setup
    eng = _mk(model, params, overload=OverloadPolicy())
    hl = eng.connect(0).submit_i(np.arange(8) % cfg.vocab_size,
                                 max_tokens=16, priority=PRIORITY_LOW)
    for _ in range(3):
        eng.tick()
    eng.connect(1).submit_i((np.arange(6) + 3) % cfg.vocab_size,
                            max_tokens=8, priority=PRIORITY_HIGH)
    eng.tick()                          # sweep preempts + binds high
    assert len(eng._parked) == 1
    parked = eng._parked[0]
    assert parked.fsm.state == states.BUFFER_PREEMPTED
    assert parked.req is hl.req and parked.generated > 0
    slot = eng.slots[0]
    assert slot.request is not None
    assert slot.request.eff_priority == PRIORITY_HIGH
    assert all(p == -1 or eng.pool.refcount(p) >= 1
               for p in eng.pool.table(parked.req.req_id).pages)
    eng.step()                          # drain: high retires, low resumes
    assert hl.wait(timeout_s=60).fsm.state == states.REQUEST_COMPLETED
    assert not eng._parked


def test_engine_cancel_while_parked(engine_setup):
    cfg, model, params = engine_setup
    eng = _mk(model, params, overload=OverloadPolicy())
    hl = eng.connect(0).submit_i(np.arange(8) % cfg.vocab_size,
                                 max_tokens=16, priority=PRIORITY_LOW)
    for _ in range(3):
        eng.tick()
    eng.connect(1).submit_i((np.arange(6) + 3) % cfg.vocab_size,
                            max_tokens=8, priority=PRIORITY_HIGH)
    eng.tick()
    assert len(eng._parked) == 1
    assert hl.cancel()
    eng.step()
    rl = hl.wait(timeout_s=60)
    assert rl.fsm.state == states.REQUEST_CANCELLED
    assert len(rl.tokens_out) > 0       # partial output delivered
    assert not eng._parked
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_engine_slo_shed(engine_setup):
    """SLO-aware shedding: a queued request past its deadline is shed
    with a typed falsy ShedStatus; one within deadline is served."""
    cfg, model, params = engine_setup
    eng = _mk(model, params,
              overload=OverloadPolicy(preemption=False, slo_s=1e-9))
    sess = eng.connect(0)
    h_shed = sess.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=4)
    h_ok = sess.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=4,
                         slo_s=300.0)   # per-request override
    eng.step()
    r_shed, r_ok = h_shed.wait(timeout_s=60), h_ok.wait(timeout_s=60)
    assert r_shed.fsm.state == states.REQUEST_CANCELLED
    assert isinstance(h_shed.status, ShedStatus) and not h_shed.status
    assert h_shed.status.slo_s == 1e-9
    assert len(r_shed.tokens_out) == 0
    assert r_ok.fsm.state == states.REQUEST_COMPLETED
    assert h_ok.status is None
    assert eng.stats["shed_requests"] == 1


def test_engine_no_starvation_under_high_flood(engine_setup):
    """Aging: a low-priority request beats a sustained high-priority
    flood into service — it does not wait for the flood to drain."""
    cfg, model, params = engine_setup
    eng = _mk(model, params, max_batch=2, pool_pages=32,
              overload=OverloadPolicy(aging_limit=2))
    s0, s1 = eng.connect(0), eng.connect(1)
    highs = [s0.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=2,
                         priority=PRIORITY_HIGH) for _ in range(10)]
    low = s1.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=2,
                      priority=PRIORITY_LOW)
    eng.step()
    rl = low.wait(timeout_s=60)
    assert rl.fsm.state == states.REQUEST_COMPLETED
    done_high = [h.wait(timeout_s=60) for h in highs]
    assert all(r.fsm.state == states.REQUEST_COMPLETED for r in done_high)
    # the low request finished before the flood's tail, not after it
    assert rl.done_t < max(r.done_t for r in done_high)


def test_engine_overload_off_is_unchanged(engine_setup):
    """overload=None keeps the legacy FIFO intake: priority argument is
    carried but ignored, counters stay zero."""
    cfg, model, params = engine_setup
    eng = _mk(model, params)
    h = eng.connect(0).submit_i(np.arange(4) % cfg.vocab_size, max_tokens=3,
                                priority=PRIORITY_HIGH, slo_s=1e-9)
    eng.step()
    r = h.wait(timeout_s=60)
    assert r.fsm.state == states.REQUEST_COMPLETED  # no shed without policy
    assert eng.stats["preemptions"] == 0
    assert eng.stats["shed_requests"] == 0
    assert isinstance(eng.intake, __import__("repro.core.host_queue",
                                             fromlist=["MpscQueue"]
                                             ).MpscQueue)
