"""Paged KV residency (scheduler="slot_paged", DESIGN.md §10): the page
pool as the device-resident KV store.  Token sequences must be
byte-identical to the dense schedulers; residency must move zero KV
bytes and scale with actual tokens, not max_len."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import states
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run_workload(model, params, scheduler, lengths, vocab, eos_id=-1,
                  **engine_kw):
    """Serve a fixed workload; returns (engine, per-request sequences in
    submission order)."""
    kw = {"max_batch": 2, "max_len": 64, "pool_pages": 256}
    kw.update(engine_kw)
    eng = ServeEngine(model, params, n_clients=1, scheduler=scheduler, **kw)
    rids = []
    for i, n in enumerate(lengths):
        r = eng.submit(0, (np.arange(4) + i) % vocab, max_tokens=n,
                       eos_id=eos_id)
        assert r is not None
        rids.append(r.req_id)
    while eng.stats["served"] + eng.stats["rejected"] < len(lengths):
        eng.step()
    got = {}
    for _ in range(len(lengths)):
        r = eng.get_response(0, timeout_s=10)
        assert r, "response timed out"
        got[r.req_id] = list(map(int, r.tokens_out))
    return eng, [got[r] for r in rids]


def test_paged_equals_fused_across_chunk_sizes(engine_setup):
    """The acceptance property: for chunk sizes 1, 4 and a whole
    bucketed prompt, slot_paged emits token sequences byte-identical to
    slot_fused — block-table indirection changes where KV lives, never
    the tokens — while performing ZERO KV copy traffic: no gather/
    scatter dispatch, no cache-copy dispatch, no dense batch cache."""
    cfg, model, params = engine_setup
    lengths = [12, 2, 7, 2, 1, 9, 24, 3]     # mixed, forces adaptive K
    e_fused, s_fused = _run_workload(model, params, "slot_fused", lengths,
                                     cfg.vocab_size)
    assert e_fused.pool.kv_copy_bytes > 0     # the copies paged deletes
    for chunk in (1, 4, 8):                   # prompts bucket to 8
        e_p, s_p = _run_workload(model, params, "slot_paged", lengths,
                                 cfg.vocab_size, chunk_tokens=chunk)
        assert s_p == s_fused, f"chunk_tokens={chunk} diverged"
        # Zero-copy residency (the acceptance criterion): after chunked
        # admission wrote KV in place, NO bytes were ever copied to
        # establish or move residency.
        assert e_p.pool.kv_copy_bytes == 0
        assert e_p.stats["cache_copy_dispatches"] == 0
        assert e_p.stats["admission_stall_steps"] == 0
        assert e_p._caches is None, "dense batch cache was allocated"
        assert e_p.pool.free_pages() == e_p.pool.n_pages


def test_paged_page_boundary_crossing_mid_block(engine_setup):
    """A fused K-step block whose decode positions cross page boundaries
    mid-block (page_size=4, K up to 8) scatters each token into the
    right (page, offset) — sequences stay identical to the scalar slot
    path and pages are accounted per boundary."""
    cfg, model, params = engine_setup
    lengths = [14, 3, 11]                     # crosses 3+ boundaries
    _, s_slot = _run_workload(model, params, "slot", lengths,
                              cfg.vocab_size, page_size=4)
    e_p, s_p = _run_workload(model, params, "slot_paged", lengths,
                             cfg.vocab_size, page_size=4, chunk_tokens=8,
                             k_max=8)
    assert s_p == s_slot
    # Cached prefixes stay resident after their writers retire (that is
    # the point — the next request hits them); only the cache holds
    # pages now, and clearing it drains the pool completely.
    e_p.prefix_cache.clear()
    assert e_p.pool.free_pages() == e_p.pool.n_pages
    assert e_p.pool.kv_copy_bytes == 0


def test_paged_eos_masking_matches_scalar(engine_setup):
    """A row that joins the decode block in the same dispatch as its
    final chunk stops exactly at EOS on the paged backend too."""
    cfg, model, params = engine_setup
    _, seqs = _run_workload(model, params, "slot_paged", [6],
                            cfg.vocab_size, chunk_tokens=4)
    eos = seqs[0][0]
    _, s_slot = _run_workload(model, params, "slot", [6, 17],
                              cfg.vocab_size, eos_id=eos)
    _, s_p = _run_workload(model, params, "slot_paged", [6, 17],
                           cfg.vocab_size, eos_id=eos, chunk_tokens=4)
    assert s_p == s_slot


def test_paged_pool_exhaustion_mid_stream_rejects(engine_setup):
    """A prompt that outgrows the pool mid-stream aborts whole: pages
    roll back, the RESERVED slot takes the direct RESERVED->FREE edge,
    and the batcher keeps serving."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=128, n_clients=1,
                      pool_pages=4, page_size=4,   # 16 tokens of KV total
                      scheduler="slot_paged", chunk_tokens=4)
    eng.submit(0, np.arange(30) % cfg.vocab_size, max_tokens=8)  # bucket 32
    eng.step()
    resp = eng.get_response(0, timeout_s=10)
    assert resp.fsm.state == states.REQUEST_CANCELLED
    assert eng.stats["rejected"] == 1
    assert eng.pool.free_pages() == eng.pool.n_pages
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE
    # the batcher is not wedged
    eng.submit(0, np.arange(4) % cfg.vocab_size, max_tokens=2)
    eng.step()
    assert eng.get_response(0, 10).fsm.state == states.REQUEST_COMPLETED


def test_paged_cancel_mid_stream_releases_reserved_slot(engine_setup):
    """cancel() while a prompt streams into pages: RESERVED->FREE, all
    pages back, no KV bytes ever moved."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=128, n_clients=1,
                      pool_pages=256, scheduler="slot_paged",
                      chunk_tokens=4)
    session = eng.connect(0)
    h1 = session.submit_i(np.arange(4) % cfg.vocab_size, max_tokens=20)
    for _ in range(3):
        eng.tick()
    h2 = session.submit_i(np.arange(40) % cfg.vocab_size, max_tokens=8)
    eng.tick()
    eng.tick()
    mid = [s for s in eng.slots
           if s.request is not None and s.generated == 0]
    assert mid and 0 < mid[0].prefill_pos < len(mid[0].prompt)
    assert h2.cancel() is True
    eng.tick()                          # abort sweep releases RESERVED slot
    r2 = h2.wait(timeout_s=10)
    assert r2.fsm.state == states.REQUEST_CANCELLED
    assert len(r2.tokens_out) == 0
    while eng.stats["served"] < 1:
        eng.tick()
    r1 = h1.wait(timeout_s=10)
    assert len(r1.tokens_out) == 20
    assert eng.pool.free_pages() == eng.pool.n_pages
    assert eng.pool.kv_copy_bytes == 0
    for slot in eng.slots:
        assert slot.fsm.state == states.BUFFER_FREE


def test_paged_resident_memory_is_length_proportional(engine_setup):
    """The memory acceptance criterion: at max_batch=8 with a mixed-
    length workload, peak paged residency is at most half the dense
    batch-cache footprint — per-slot memory is O(actual tokens), not
    O(max_len)."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=8, max_len=128, n_clients=1,
                      pool_pages=64, page_size=16, intake_depth=32,
                      scheduler="slot_paged", chunk_tokens=16)
    # Mixed lengths: prompts 4..20 (buckets 8..32), budgets 2..24.
    work = [(4, 2), (12, 24), (4, 8), (20, 4), (7, 16), (4, 2), (9, 12),
            (16, 6)]
    for i, (plen, mt) in enumerate(work):
        assert eng.submit(0, (np.arange(plen) + i) % cfg.vocab_size,
                          max_tokens=mt) is not None
    # The first tick's admission sweep binds every slot at once (worst
    # concurrency — captured by the peak counter); short requests may
    # already retire inside it, so sample live residency right after.
    eng.tick()
    mid_resident = eng.pool.stats()["kv_resident_bytes"]
    assert mid_resident > 0
    while eng.stats["served"] < len(work):
        eng.step()
    for _ in range(len(work)):
        assert eng.get_response(0, timeout_s=10)
    # Retired writers leave their shareable prefixes resident in the
    # cache on purpose; drop them so the zero-residency drain assert
    # below measures live sequences only.
    eng.prefix_cache.clear()
    stats = eng.pool.stats()
    dense = eng.dense_cache_bytes()
    assert stats["kv_resident_bytes_peak"] <= 0.5 * dense, (stats, dense)
    assert mid_resident <= 0.5 * dense
    assert stats["kv_resident_bytes"] == 0          # all pages returned
    assert stats["kv_copy_bytes"] == 0
    assert eng._caches is None


def test_paged_streaming_delivers_every_position_once(engine_setup):
    """The streaming surface rides the paged scheduler unchanged: every
    position exactly once, in order, with the terminal recovering any
    backpressure drops."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=64, n_clients=1,
                      pool_pages=256, scheduler="slot_paged")
    session = eng.connect(0)
    h = session.submit_i(np.arange(5) % cfg.vocab_size, max_tokens=12)
    got = {}
    it = h.tokens(timeout_s=10)
    while True:
        eng.step()
        if h.test():
            break
    for pos, tok in it:
        assert pos not in got
        got[pos] = tok
    assert sorted(got) == list(range(12))
    assert list(h.response.tokens_out) == [got[p] for p in range(12)]


def test_paged_rejects_unpageable_arch(engine_setup):
    """Recurrent state cannot be paged: the constructor refuses with a
    clear error instead of corrupting pages."""
    cfg = get_smoke_config("zamba2-2.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="slot_paged"):
        ServeEngine(model, params, scheduler="slot_paged")
