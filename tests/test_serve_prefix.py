"""Prefix-sharing page pool (DESIGN.md §11): copy-on-write block tables
over lock-free refcounted pages.  A cached prefix hit must admit with
zero prefill dispatches and zero KV traffic; divergence must copy
exactly the diverged pages (and only for the writer); and through all of
it token sequences stay byte-identical to the cold path."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import states
from repro.models.model import build_model
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import OK, POOL_FULL, PagedKVPool

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(model, params, prompts, max_tokens, *, prefix_cache=True,
           drain_after_first=False, **engine_kw):
    """Serve ``prompts`` in order; returns (engine, token sequences in
    submission order).  ``drain_after_first`` completes the first request
    (the cache writer) before the rest are submitted as a burst."""
    kw = {"max_batch": 2, "max_len": 32, "pool_pages": 64, "page_size": 4}
    kw.update(engine_kw)
    eng = ServeEngine(model, params, n_clients=1, scheduler="slot_paged",
                      prefix_cache=prefix_cache, **kw)
    rids = []
    for j, p in enumerate(prompts):
        r = eng.submit(0, np.asarray(p, np.int32), max_tokens=max_tokens)
        assert r is not None
        rids.append(r.req_id)
        if drain_after_first and j == 0:
            while eng.stats["served"] < 1:
                eng.step()
    while eng.stats["served"] + eng.stats["rejected"] < len(prompts):
        eng.step()
    got = {}
    for _ in range(len(prompts)):
        r = eng.get_response(0, timeout_s=10)
        assert r, "response timed out"
        got[r.req_id] = list(map(int, r.tokens_out))
    return eng, [got[r] for r in rids]


def test_prefix_hit_equals_cold_across_chunk_sizes(engine_setup):
    """The acceptance property: four requests sharing a 12-token system
    prefix produce token sequences byte-identical with the cache on and
    off, at chunk_tokens 1, 4 and 8 — while the hits skip exactly the
    cached chunks (no dispatch, no KV copy: the shared extent here is
    page-aligned, so not even a CoW fires)."""
    cfg, model, params = engine_setup
    shared = [(i * 5 + 2) % cfg.vocab_size for i in range(12)]
    prompts = [shared + [(100 + 7 * j + i) % cfg.vocab_size
                         for i in range(4)] for j in range(4)]   # bucket 16
    for chunk, e_hit in [(1, 12), (4, 12), (8, 8)]:
        e_off, s_off = _serve(model, params, prompts, 6,
                              prefix_cache=False, chunk_tokens=chunk,
                              drain_after_first=True)
        e_on, s_on = _serve(model, params, prompts, 6,
                            chunk_tokens=chunk, drain_after_first=True)
        assert s_on == s_off, f"chunk_tokens={chunk} diverged"
        assert e_on.stats["prefix_hits"] == 3
        assert e_on.stats["prefill_tokens_saved"] == 3 * e_hit
        # Chunk math: cold pays 4 whole prompts; hits resume at e_hit.
        assert e_off.stats["prefill_chunks"] == 4 * (16 // chunk)
        assert e_on.stats["prefill_chunks"] == (16 // chunk
                                                + 3 * (16 - e_hit) // chunk)
        # Page-aligned sharing is zero-copy: hits adopt rows, never copy.
        assert e_on.pool.kv_copy_bytes == 0
        assert e_on.pool.cow_copy_bytes == 0
        assert e_on.pool.stats()["shared_pages_peak"] > 0


def test_cow_on_divergence_copies_one_page_each_way(engine_setup):
    """Divergence inside a shared page: B shares A's first 6 tokens
    (page_size=4 — the hit's trailing page is half A's, half B's), so
    B's first chunk must CoW exactly ONE page before writing.  A
    re-submission of A's exact prompt afterwards still hits and still
    matches A byte-for-byte — B's divergence never touched the shared
    physical pages."""
    cfg, model, params = engine_setup
    base = [(i * 3 + 5) % cfg.vocab_size for i in range(6)]
    pa = base + [11, 12]                     # bucket 8
    pb = base + [201, 202]                   # diverges at position 6
    kw = dict(chunk_tokens=2, max_len=16, pool_pages=32, page_size=4)
    e_off, s_off = _serve(model, params, [pa, pb, pa], 4,
                          prefix_cache=False, drain_after_first=True, **kw)
    eng, seqs = _serve(model, params, [pa, pb, pa], 4,
                       drain_after_first=True, **kw)
    assert seqs == s_off                     # writer, divergent, re-hit
    assert seqs[2] == seqs[0], "sharer's tokens changed under B's CoW"
    assert eng.stats["prefix_hits"] == 2     # B and the A re-run hit E=6
    # Exactly one page copied per diverging writer (B rewrites positions
    # 6-7 of shared page 1; A2 rewrites the same positions of its own) —
    # and CoW is the ONLY KV traffic the paged path ever performs.
    assert eng.pool.cow_copy_bytes == 2 * eng.pool.page_nbytes
    assert eng.pool.kv_copy_bytes == eng.pool.cow_copy_bytes


def test_cancel_mid_decode_releases_refs_not_pages(engine_setup):
    """A hit sequence cancelled mid-decode gives back its page
    references; the cached prefix stays resident (never freed out from
    under the cache) and the entries the aborted sequence itself
    published roll back — the next identical request hits the intact
    prefix and reproduces the original tokens."""
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_batch=2, max_len=32, n_clients=1,
                      pool_pages=64, page_size=4, scheduler="slot_paged",
                      chunk_tokens=4, k_max=2)
    prompt = np.asarray([(i * 9 + 4) % cfg.vocab_size for i in range(12)],
                        np.int32)            # bucket 16
    session = eng.connect(0)
    ha = session.submit_i(prompt, max_tokens=2)
    while eng.stats["served"] < 1:
        eng.tick()
    ra = ha.wait(timeout_s=10)
    resident = eng.prefix_cache.resident_pages()
    assert resident                          # E=4/8/12 prefixes cached
    hb = session.submit_i(prompt, max_tokens=12)
    while not any(s.request is not None and s.generated >= 2
                  for s in eng.slots):
        eng.tick()
    assert eng.stats["prefix_hits"] == 1
    assert hb.cancel() is True
    eng.tick()                               # abort sweep
    rb = hb.wait(timeout_s=10)
    assert rb.fsm.state == states.REQUEST_CANCELLED
    # B's references released, B's own published entries rolled back —
    # but the pages A's entries cover are exactly as resident as before.
    assert eng.prefix_cache.resident_pages() == resident
    assert eng.pool.used_pages() == len(resident)
    assert eng.pool.free_pages() == eng.pool.n_pages - len(resident)
    assert eng.pool.n_seqs() == 0
    hc = session.submit_i(prompt, max_tokens=2)
    while eng.stats["served"] < 2:
        eng.tick()
    rc = hc.wait(timeout_s=10)
    assert list(rc.tokens_out) == list(ra.tokens_out)
    assert eng.stats["prefix_hits"] == 2


def test_eviction_under_pressure_admits_instead_of_rejecting(engine_setup):
    """Pool pressure evicts unreferenced cached prefixes before any
    claim fails: a pool that cannot hold the cache residue AND a new
    admission serves the new request anyway (LRU entries yield their
    pages) — and the tokens still match the cache-off run."""
    cfg, model, params = engine_setup
    prompts = [[(i * 13 + 31 * j + 1) % cfg.vocab_size for i in range(8)]
               for j in range(4)]            # distinct: all misses
    kw = dict(max_batch=1, max_len=16, pool_pages=8, page_size=4,
              chunk_tokens=4)
    e_off, s_off = _serve(model, params, prompts, 4,
                          prefix_cache=False, **kw)
    eng, seqs = _serve(model, params, prompts, 4, **kw)
    assert seqs == s_off
    assert eng.stats["served"] == 4
    assert eng.stats["rejected"] == 0, "pressure eviction failed to free"
    assert eng.prefix_cache.evictions > 0


# ---------------------------------------------------------------------------
# Pool-level: refcounted claim/rollback/accounting under sharing.
# ---------------------------------------------------------------------------
def _pool(n_pages=4, page_size=4):
    return PagedKVPool(n_pages, page_size, n_layers=2, kv_heads=2,
                       head_dim=4)


def test_pool_resident_bytes_count_physical_pages_once():
    """kv_resident_bytes is physical: two sequences (plus the cache)
    sharing the same four pages cost four pages, not twelve."""
    pool = _pool(n_pages=8)
    assert pool.try_admit(0, 16) == OK       # 4 pages
    pages = list(pool.table(0).pages)
    pool.incref_pages(pages)                 # cache residency
    pool.adopt_shared(1, pages, 16)
    assert pool.used_pages() == 4
    assert pool.stats()["kv_resident_bytes"] == 4 * pool.page_nbytes
    assert pool.stats()["shared_pages"] == 4
    pool.free(0)
    pool.free(1)
    assert pool.used_pages() == 4            # cache still holds them
    pool.decref_pages(pages)
    assert pool.used_pages() == 0


def test_pool_partial_claim_rollback_never_frees_shared_pages():
    """All-or-nothing under sharing: an extend_reservation that cannot
    complete rolls back exactly the fresh pages it claimed — the shared
    pages the sequence adopted keep every reference, and retrying with a
    feasible size succeeds."""
    pool = _pool(n_pages=4)
    assert pool.try_admit(0, 8) == OK        # 2 pages
    shared = list(pool.table(0).pages)
    pool.incref_pages(shared)                # cache residency
    pool.adopt_shared(1, shared, 8)
    assert all(pool.refcount(p) == 3 for p in shared)
    # seq 1 wants 6 pages total; only 2 are free -> POOL_FULL, and the
    # partial claim (2 fresh pages) is returned exactly once.
    assert pool.extend_reservation(1, 24) == POOL_FULL
    assert all(pool.refcount(p) == 3 for p in shared)
    assert pool.free_pages() == 2
    assert pool.extend_reservation(1, 16) == OK
    assert pool.free_pages() == 0
    pool.free(1)                             # drops 1 ref on shared pages
    assert all(pool.refcount(p) == 2 for p in shared)
    pool.free(0)
    assert all(pool.refcount(p) == 1 for p in shared)
    pool.decref_pages(shared)
    assert pool.free_pages() == pool.n_pages


def test_pool_cow_exhaustion_fails_clean():
    """ensure_private with no free page: POOL_FULL, no refcount drift,
    no block-table mutation — the caller aborts the sequence whole."""
    pool = _pool(n_pages=4)
    assert pool.try_admit(0, 8) == OK
    shared = list(pool.table(0).pages)
    pool.incref_pages(shared)
    pool.adopt_shared(1, shared, 8)
    assert pool.try_admit(2, 8) == OK        # fills the pool
    assert pool.free_pages() == 0
    assert pool.ensure_private(1, 0, 8) == POOL_FULL
    assert list(pool.table(1).pages) == shared
    assert all(pool.refcount(p) == 3 for p in shared)
    assert pool.cow_copy_bytes == 0


def test_pool_cow_copies_only_shared_rows():
    """ensure_private repoints exactly the rows another holder can read:
    private rows in the range are untouched, the old page stays resident
    for its other holders, and the traffic counters charge exactly the
    copied pages."""
    pool = _pool(n_pages=8)
    assert pool.try_admit(0, 12) == OK       # 3 pages
    pages = list(pool.table(0).pages)
    pool.incref_pages(pages[:2])             # cache holds first 2 only
    assert pool.ensure_private(0, 8, 12) == OK
    assert pool.cow_copy_bytes == 0          # row 2 was already private
    assert pool.ensure_private(0, 4, 12) == OK
    t = pool.table(0)
    assert t.pages[0] == pages[0]            # outside the write range
    assert t.pages[1] != pages[1]            # CoW'd
    assert t.pages[2] == pages[2]
    assert pool.refcount(pages[1]) == 1      # cache keeps the original
    assert pool.refcount(t.pages[1]) == 1
    assert pool.cow_copy_bytes == pool.page_nbytes
    assert pool.kv_copy_bytes == pool.page_nbytes
