"""NBB ring pipeline: pipeline parallelism as a lock-free circular buffer.

The paper's NBB (Kim'07) is a FIFO ring where a producer and a consumer
synchronize through two counters and never touch the same slot.  Mapped
onto a TPU mesh axis (DESIGN.md §2), the *stages* of a pipeline-parallel
model are the tasks, `collective_permute` edges are the MCAPI channels,
and the microbatch slots rotating around the ring are the NBB buffer:

  * producer counter  = microbatches injected at stage 0 (tick index t),
  * consumer counter  = microbatches retired at stage S-1 (t - (S-1)),
  * slot disjointness = each stage holds exactly one in-flight microbatch
    per tick, by construction — no global barrier, no lock.

Three schedules are provided, mirroring the paper's lock-based vs
lock-free test matrix:

  "barrier"  — the *lock-based analogue*: every tick all-gathers every
               stage's activation over the stage axis and each stage
               selects its input.  This is exactly the reference MCAPI
               design: one global shared-memory partition all writers
               and readers serialize through.  Collective bytes per tick
               scale with the number of stages.
  "nbb"      — the lock-free ring: one point-to-point permute per tick.
               Collective bytes per tick are one activation, independent
               of stage count — the paper's 25x insight, reproduced at
               the collective-bytes level in benchmarks/bench_pipeline.
  "nbb2"     — the 2-slot double-buffered ring (ring_depth=2): the send
               of tick t-1 has no data dependence on the compute of tick
               t, so the compiler can overlap DMA with the MXU — the
               device analogue of NBB's producer running ahead of the
               consumer.

All schedules compute identical values (property-tested); they differ
only in collective schedule — which is the paper's whole point.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map_compat


def _stage_slice(stage_params, n_stages):
    """shard_map hands each device its [1, ...]-leading slice; drop it."""
    return jax.tree.map(lambda a: a[0], stage_params)


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any,
                   microbatches: jax.Array,
                   mesh,
                   axis: str = "model",
                   schedule: str = "nbb") -> jax.Array:
    """Run ``microbatches`` through ``n_stages`` pipeline stages.

    stage_fn(params_for_stage, x[mb, ...]) -> y[mb, ...] (same shape).
    stage_params: pytree with leading dim == mesh.shape[axis] (one slice
      per stage).
    microbatches: [n_micro, mb, ...].
    Returns [n_stages, n_micro, mb, ...], sharded over ``axis`` on dim 0;
    ``result[-1]`` (index it *outside* jit to keep the transfer local) is
    the final-stage output.  Keeping delivery out of the step function
    means the compiled program contains only the schedule's own
    collectives — measurable and minimal.
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    assert schedule in ("barrier", "nbb", "nbb2")

    def run(local_params, mb_local):
        params = _stage_slice(local_params, n_stages)
        sid = jax.lax.axis_index(axis)
        first = sid == 0
        last = sid == n_stages - 1
        zero = jnp.zeros(mb_local.shape[1:], mb_local.dtype)
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        if schedule in ("barrier", "nbb"):
            n_ticks = n_micro + n_stages - 1

            def tick(buf, t):
                # stage 0 consumes the next microbatch; others their buffer
                inj = jax.lax.cond(
                    t < n_micro,
                    lambda: jax.lax.dynamic_index_in_dim(
                        mb_local, jnp.minimum(t, n_micro - 1), 0,
                        keepdims=False),
                    lambda: zero)
                x = jnp.where(first, inj, buf)
                y = stage_fn(params, x)
                if schedule == "nbb":
                    nxt = jax.lax.ppermute(y, axis, fwd)
                else:
                    # lock-based analogue: global exchange, local select
                    all_y = jax.lax.all_gather(y, axis)      # [S, mb, ...]
                    nxt = jax.lax.dynamic_index_in_dim(
                        all_y, jnp.maximum(sid - 1, 0), 0, keepdims=False)
                    nxt = jnp.where(first, zero, nxt)
                return nxt, jnp.where(last, y, zero)

            _, outs = jax.lax.scan(tick, zero, jnp.arange(n_ticks))
            outs = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, n_micro, 0)

        else:  # nbb2: 2-slot ring, send decoupled from compute
            # Each hop takes 2 ticks (slot fill, slot drain) but the
            # permute of slot w-1 is independent of the compute filling
            # slot w -> overlap.  Stage s sees microbatch m at tick
            # 2*s + m; total ticks = 2*(S-1) + n_micro.
            n_ticks = 2 * (n_stages - 1) + n_micro

            def tick(carry, t):
                held, to_send = carry          # two NBB slots
                sent = jax.lax.ppermute(to_send, axis, fwd)   # drain slot
                inj = jax.lax.cond(
                    t < n_micro,
                    lambda: jax.lax.dynamic_index_in_dim(
                        mb_local, jnp.minimum(t, n_micro - 1), 0,
                        keepdims=False),
                    lambda: zero)
                x = jnp.where(first, inj, held)
                y = stage_fn(params, x)                        # fill slot
                return (sent, y), jnp.where(last, y, zero)

            _, outs = jax.lax.scan(tick, (zero, zero), jnp.arange(n_ticks))
            # stage S-1 computes microbatch m at tick 2*(S-1) + m
            outs = jax.lax.dynamic_slice_in_dim(
                outs, 2 * (n_stages - 1), n_micro, 0)

        # Each stage returns its own outs slab; stacking over the stage
        # axis (out_specs P(axis)) delivers without any extra collective —
        # the consumer indexes the last stage's slab.  (An earlier psum
        # delivery added an all-reduce that dwarfed the schedules' own
        # traffic and hid the barrier-vs-ring difference.)
        return outs[None]

    shard_f = shard_map_compat(
        run, mesh=mesh,
        in_specs=(P(axis), P()),       # params split by stage; mbs replicated
        out_specs=P(axis),             # [n_stages, n_micro, mb, ...]
    )
    return shard_f(stage_params, microbatches)


def pipeline_reference(stage_fn, stage_params, microbatches, n_stages):
    """Oracle: sequential stage application, no mesh."""
    def apply_all(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(apply_all)(microbatches)
