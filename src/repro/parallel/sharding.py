"""Logical-axis sharding rules (MaxText-style) for pjit/GSPMD.

Models annotate activations/params with *logical* axis names ("batch",
"embed", "mlp", ...).  A rules table maps logical names to mesh axes; the
table + mesh are installed with :func:`axis_rules` around tracing.  Outside
any rules context (CPU smoke tests) every annotation is a no-op, so the same
model code runs unsharded on one device and sharded on 512.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: the public API (with
    ``check_vma``) when present, else ``jax.experimental.shard_map``
    (whose equivalent knob is ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)

# Default rules for the production meshes of DESIGN.md §7.
# "batch" spreads over pod+data; "model"-parallel dims over the model axis.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,                # sequence kept local by default
    "seq_model": "model",       # context-parallel sequence (long ctx / big attn)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": "model",             # flattened attention projection dim
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "expert_data": "data",      # FSDP-style extra shard for expert weights
    "cache_seq": "model",       # decode KV cache: shard seq over model
    "cache_kv_heads": None,
    "conv_kernel": None,
    "state": None,
    "layers": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, MeshAxes]] = None


_ctx = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    """Install (mesh, rules) for `shard()`/`spec_for()` during tracing."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # Drop references to mesh axes the mesh doesn't have (e.g. "pod" on the
    # single-pod mesh) so one rules table serves both meshes.
    have = set(mesh.axis_names)

    def _filter(v: MeshAxes) -> MeshAxes:
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in have else None
        kept = tuple(a for a in v if a in have)
        return kept if kept else None

    merged = {k: _filter(v) for k, v in merged.items()}
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh, _ctx.rules = mesh, merged
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def active() -> bool:
    return _ctx.mesh is not None


def spec_for(names: Sequence[Optional[str]]) -> P:
    """Logical axis names -> PartitionSpec under the active rules."""
    assert _ctx.rules is not None
    entries = []
    used = set()
    for n in names:
        v = _ctx.rules.get(n) if n is not None else None
        # A mesh axis may appear at most once in a spec; later dims lose.
        if isinstance(v, str):
            v = (v,) if v not in used else None
        elif isinstance(v, tuple):
            v = tuple(a for a in v if a not in used) or None
        if v is not None:
            used.update(v if isinstance(v, tuple) else (v,))
            entries.append(v if len(v) > 1 else v[0])
        else:
            entries.append(None)
    return P(*entries)


def sharding_for(names: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(_ctx.mesh, spec_for(names))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op outside a rules context."""
    if not active():
        return x
    if len(names) != x.ndim:
        raise ValueError(f"shard(): {len(names)} names for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(x, sharding_for(names))


class Axes:
    """Logical axes metadata for one parameter (a pytree *leaf*)."""

    __slots__ = ("names",)

    def __init__(self, *names: Optional[str]):
        self.names = tuple(names)

    def prepend(self, name: Optional[str]) -> "Axes":
        return Axes(name, *self.names)

    def __repr__(self):
        return f"Axes{self.names}"

    def __eq__(self, other):
        return isinstance(other, Axes) and self.names == other.names

    def __hash__(self):
        return hash(self.names)


def specs_tree(axes_tree):
    """Map a tree of Axes -> tree of PartitionSpec under active rules."""
    return jax.tree.map(
        lambda a: spec_for(a.names),
        axes_tree,
        is_leaf=lambda x: isinstance(x, Axes),
    )


def shardings_tree(axes_tree):
    return jax.tree.map(
        lambda a: sharding_for(a.names),
        axes_tree,
        is_leaf=lambda x: isinstance(x, Axes),
    )
