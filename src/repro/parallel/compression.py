"""Gradient compression with error feedback for cross-pod all-reduce.

At 512+ chips the data-parallel gradient all-reduce crosses the (slow)
pod axis; int8 quantization cuts those collective bytes 4x vs f32 (2x vs
bf16).  Error feedback (residual carried to the next step) keeps SGD
convergence — the quantization error is re-injected instead of lost, so
the compressed update telescopes to the true gradient sum.

This composes with the paper's framing: the gradient exchange is one
more producer/consumer channel; compression shrinks the message payload
exactly like the paper's "combine multiple messages into a single packet
buffer" §6 recommendation shrinks per-message overhead.

All functions are pure; the error-feedback state is threaded explicitly
(a pytree congruent with the grads).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params: Any) -> Any:
    """Zero residual per parameter (f32 — it holds sub-int8 mass)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """(grads, err) -> (compressed {q, scale} tree, new err).

    Error feedback: compress (g + err); the new err is what int8 lost.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        recon = dequantize_int8(q, scale)
        return {"q": q, "scale": scale}, target - recon

    flat = jax.tree.map(one, grads, err,
                        is_leaf=lambda x: isinstance(x, jax.Array)
                        or hasattr(x, "shape"))
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


def decompress_grads(comp: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda leaf: dequantize_int8(leaf["q"], leaf["scale"], dtype),
        comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_psum(grads: Any, err: Any, axis: str,
                    n_shards: Optional[int] = None) -> Tuple[Any, Any]:
    """All-reduce grads over ``axis`` in int8 (inside shard_map).

    Each shard quantizes (g + err) locally, the int8 payloads are summed
    with ``psum`` (s32 accumulate to avoid overflow at <= 2^23 shards),
    and every shard dequantizes with the max scale.  Returns the mean
    gradient and the new error state.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        # shared scale: every shard must use the same dequant factor
        scale_max = jax.lax.pmax(scale, axis)
        # requantize against the shared scale so sums are consistent
        q_shared = jnp.clip(
            jnp.round(target / scale_max), -127, 127).astype(jnp.int8)
        recon_shared = q_shared.astype(jnp.float32) * scale_max
        total = jax.lax.psum(q_shared.astype(jnp.int32), axis)
        n = n_shards or jax.lax.psum(jnp.ones((), jnp.int32), axis)
        mean = total.astype(jnp.float32) * scale_max / n
        return mean.astype(g.dtype), target - recon_shared

    pairs = jax.tree.map(one, grads, err)
    mean = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err
