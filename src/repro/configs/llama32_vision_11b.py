"""Llama-3.2-Vision-11B — dense decoder with cross-attention image layers
every 5th layer; vision frontend stubbed (input_specs provides precomputed
patch embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5, num_image_tokens=1600,
    tie_embeddings=False,
    mesh_rules={"heads": None, "kv_heads": None},
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    cross_attn_every=5, num_image_tokens=16,
    tie_embeddings=False,
)
