"""Snowflake Arctic 480B — 128-expert top-2 MoE with a parallel dense
residual MLP per layer.  [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.models.config import ModelConfig, MoEConfig

# 56 heads / 8 kv: attention replicated over model; experts sharded 128/16
# and FSDP-sharded over data (ZeRO-3 gather in the MoE block) so the 468B
# expert params fit 16 GB/chip.  Optimizer state is 8-bit (train config).
CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  capacity_factor=1.25, dense_residual=True),
    tie_embeddings=False,
    mesh_rules={"heads": None, "kv_heads": None},
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96,
                  dense_residual=True),
    tie_embeddings=False,
)
