"""Zamba2-2.7B — hybrid: Mamba2 backbone + one shared attention block
applied every 6 layers.  [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk=64),
    attn_every=6,
    tie_embeddings=True, supports_long_context=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=32, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=8,
    ssm=SSMConfig(state_dim=8, head_dim=8, expand=2, conv_kernel=4, chunk=8),
    attn_every=2,
    tie_embeddings=True, supports_long_context=True,
)
