"""Architecture registry: the 10 assigned archs + the paper's bench config.

Each module exposes CONFIG (exact published dims) and SMOKE (reduced config
of the same family for CPU tests).  ``get_config(name)`` / ``list_archs()``
are the public API; ``--arch <id>`` on every launcher resolves here.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "smollm-135m": "smollm_135m",
    "gemma3-27b": "gemma3_27b",
    "qwen3-14b": "qwen3_14b",
    "stablelm-3b": "stablelm_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-tiny": "whisper_tiny",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def shapes_for(name: str) -> List[ShapeConfig]:
    """The assigned shape cells for this arch (with documented skips)."""
    cfg = get_config(name)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # pure full-attention: no sub-quadratic path (DESIGN §4)
        out.append(s)
    return out
