"""Whisper-tiny — encoder-decoder; conv audio frontend stubbed (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    encoder=EncoderConfig(num_layers=4, num_frames=1500),
    tie_embeddings=True,
    # 6 heads and vocab 51865 don't divide the 16-way model axis; the
    # model is tiny (39 MB embed) so replicate those dims.
    mesh_rules={"heads": None, "kv_heads": None, "vocab": None},
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=8,
    encoder=EncoderConfig(num_layers=2, num_frames=24),
    tie_embeddings=True,
)
