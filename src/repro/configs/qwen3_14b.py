"""Qwen3-14B — dense GQA with qk-norm.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

# 40 heads / 8 kv heads don't divide 16: attention replicated over model.
CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    tie_embeddings=False,
    mesh_rules={"heads": None, "kv_heads": None},
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, qk_norm=True,
    tie_embeddings=False,
)
