"""StableLM-3B — dense MHA (kv == heads).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304, head_dim=80,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense",
    num_layers=2, d_model=48, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=256, head_dim=12, tie_embeddings=False,
)
