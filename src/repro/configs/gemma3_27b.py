"""Gemma3-27B — dense, 5:1 local(sliding-window 1024):global attention,
qk-norm, 262k vocab, 128k context.  [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

# Sub-quadratic majority (sliding-window locals) -> long_500k runs; the
# few global layers shard their 500k KV cache over the model axis.
CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    sliding_window=1024, local_global_ratio=5,
    tie_embeddings=True, supports_long_context=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    num_layers=7, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    qk_norm=True, sliding_window=8, local_global_ratio=2,
    tie_embeddings=True, supports_long_context=True,
)
