"""OLMoE-1B-7B — 64-expert top-8 MoE.  [arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                  capacity_factor=1.25),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=4, d_ff_expert=64),
    tie_embeddings=False,
)
