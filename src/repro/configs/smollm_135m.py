"""SmolLM-135M — llama-arch small dense LM.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models.config import ModelConfig

# 9 heads / 3 kv heads don't divide the 16-way model axis: attention
# projections stay replicated over "model" (MLP/vocab still sharded).
CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64,
    tie_embeddings=True,
    mesh_rules={"heads": None, "kv_heads": None},
    # small vocab + wide DP/SP: batch-preserving xent chunks win (§Perf)
    xent_layout="batched",
)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, tie_embeddings=True,
)
