"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536, head_dim=64,
    rwkv=RWKVConfig(head_dim=64, chunk=16, decay_lora=64),
    tie_embeddings=False, supports_long_context=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=8,
    rwkv=RWKVConfig(head_dim=8, chunk=4, decay_lora=8),
    tie_embeddings=False, supports_long_context=True,
)
