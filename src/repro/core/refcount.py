"""Lock-free reference-counted slot array — the bitset, generalized.

The paper's refactoring step (3) replaced a lock-free linked list with a
lock-free *bit set* because the pool only needed claim/release — a binary
own/free discipline.  Prefix-shared KV pages break that binary: one
physical page can back many sequences' block-table rows at once, plus the
prefix cache's own residency, so the allocator must count owners.  This
module is the bitset's refcounted generalization with the same
non-blocking contract:

  * ``try_claim``   — CAS claim-from-zero (a free slot becomes count 1)
  * ``incref``      — fetch-add share (a held slot gains an owner)
  * ``decref``      — fetch-sub release; the slot returns to the free set
                      exactly when the count reaches zero

CPython gives no atomic integer fetch-add, so the count is *represented*
rather than stored: each slot holds a dict of unique reference tokens and
the count IS ``len()`` of that dict.  Inserting a fresh token
(``d[object()] = None``) and ``popitem()`` are single atomic dict
operations under the GIL, so incref/decref are wait-free and never lose
an update — two racing increfs insert two distinct keys; two racing
decrefs pop two distinct items.

Claim-from-zero is the one transition that must be mutually exclusive
*between claimers*: two threads observing ``len == 0`` must not both
insert a first token.  A per-slot setdefault-CAS guard (the HostBitset
primitive) serializes claimers only — a claimer that loses the guard
probes the next slot, never blocks.  Holders never touch the guard, and
when a slot's count is zero there are no holders by definition (sharing
requires already holding a reference), so the guarded claim races only
against other claimers — which is exactly what the guard excludes.
"""
from __future__ import annotations

from typing import Optional

from repro.core import interleave as _il

_MISSING = object()


class RefCountArray:
    """Lock-free refcounted slot allocator (multi-thread safe).

    The free set is implicit: slot ``i`` is free iff its count is zero.
    ``release`` is an alias for ``decref`` so the array is drop-in for
    :class:`repro.core.bitset.HostBitset` in single-owner use — a page
    that was never shared releases straight back to the free set.
    """

    __slots__ = ("_n", "_refs", "_claiming")

    def __init__(self, nslots: int):
        self._n = nslots
        # slot -> {token: None}; the count of slot i is len(self._refs[i]).
        self._refs = [dict() for _ in range(nslots)]
        # slot -> claimer token; serializes claim-from-zero attempts only.
        self._claiming: dict = {}

    @property
    def capacity(self) -> int:
        return self._n

    # -- claim-from-zero (CAS) ---------------------------------------------
    def try_claim(self, owner: object = True, start: int = 0
                  ) -> Optional[int]:
        """Claim any free slot (count 0 -> 1); index or None when all held.

        Obstruction-free probing like ``HostBitset.try_claim``: a probe
        that loses the per-slot guard or finds the slot referenced moves
        on; some claimer always makes progress.  ``owner`` is accepted
        for signature compatibility; references are anonymous tokens.
        """
        del owner
        n = self._n
        for off in range(n):
            i = (start + off) % n
            if _il._active is not None:
                _il._active.yield_point("refcount.probe", (id(self), i))
            if not self._refs[i] and self.claim_specific(i):
                return i
        return None

    def claim_specific(self, i: int) -> bool:
        """CAS claim slot ``i`` iff it is free.  True when we took it."""
        tok = object()
        if _il._active is not None:
            _il._active.yield_point("refcount.guard", (id(self), i))
        if self._claiming.setdefault(i, tok) is not tok:
            return False           # another claimer holds the guard
        try:
            if _il._active is not None:
                _il._active.yield_point("refcount.check", (id(self), i))
            if self._refs[i]:      # referenced -> not free, claim fails
                return False
            # No holders exist (count == 0) and rival claimers are
            # excluded by the guard: inserting the first reference is
            # race-free.
            if _il._active is not None:
                _il._active.yield_point("refcount.insert", (id(self), i))
            self._refs[i][object()] = None
            return True
        finally:
            if _il._active is not None:
                _il._active.yield_point("refcount.unguard", (id(self), i))
            self._claiming.pop(i, None)

    # -- share / release (fetch-add / fetch-sub) ---------------------------
    def incref(self, i: int) -> int:
        """Share a held slot; returns the new count.

        Contract: the caller already holds a reference to ``i`` (you can
        only share what you own), so the count stays >= 1 throughout and
        cannot race a concurrent return-to-free.
        """
        d = self._refs[i]
        if not d:
            raise KeyError(f"slot {i} is free; incref requires a holder")
        if _il._active is not None:
            _il._active.yield_point("refcount.incref", (id(self), i))
        d[object()] = None         # unique key: atomic, never lost
        return len(d)

    def decref(self, i: int) -> int:
        """Drop one reference; returns the remaining count.  The slot
        re-enters the free set exactly when this returns 0 — there is no
        separate "free" step to forget or double-run."""
        if _il._active is not None:
            _il._active.yield_point("refcount.decref", (id(self), i))
        try:
            self._refs[i].popitem()    # atomic removal of one reference
        except KeyError:
            raise KeyError(f"slot {i} is free; decref without a reference")
        return len(self._refs[i])

    # HostBitset-compatible surface --------------------------------------
    def release(self, i: int) -> None:
        self.decref(i)

    def refcount(self, i: int) -> int:
        return len(self._refs[i])

    def is_claimed(self, i: int) -> bool:
        return bool(self._refs[i])

    def count(self) -> int:
        """Number of *held* slots (each counted once however shared)."""
        return sum(1 for d in self._refs if d)

    def shared_count(self) -> int:
        """Number of slots currently held by more than one reference."""
        return sum(1 for d in self._refs if len(d) > 1)
