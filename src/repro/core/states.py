"""Finite-state machines with lock-free CAS transitions.

Refactoring step (4) of the paper: boolean validity flags on request and
queue-entry objects were replaced by explicit finite state machines whose
transitions are performed with atomic compare-and-swap — "verify with atomic
compare-and-swap that an object is in the expected state before changing to
the next state" (Section 3, Figures 3 and 4).

The two FSMs from the paper are reproduced (with one extension):

  Request:  FREE -> VALID -> {RECEIVED -> {COMPLETED, CANCELLED},
                              COMPLETED, CANCELLED}
            COMPLETED -> FREE, CANCELLED -> FREE
  Buffer:   FREE -> RESERVED -> ALLOCATED -> {RECEIVED -> FREE,
                                              PREEMPTED -> {ALLOCATED,
                                                            FREE}}

The RECEIVED -> CANCELLED edge extends the paper's Figure 3 for
client-initiated cancellation of an *in-service* request (the streaming
session API): the client's ``cancel()`` races the server's completion
with a single CAS, so exactly one of COMPLETED/CANCELLED wins and the
server releases resources exactly once either way.  The buffer FSM
likewise gains a RESERVED -> FREE edge so a chunked admission whose
prompt is still streaming into the cache can be aborted without ever
reaching ALLOCATED (DESIGN.md §9), and a PREEMPTED state for the
overload-control subsystem (DESIGN.md §12): an ALLOCATED sequence whose
private KV pages were swapped host-side parks in PREEMPTED, resumes via
PREEMPTED -> ALLOCATED when pages are re-claimed, or exits via
PREEMPTED -> FREE when the client cancels it while parked.

A third, two-state FSM backs the MCAPI-style non-blocking operation
handles (``repro.core.transport.OpHandle``):

  Op:       PENDING -> {COMPLETED, CANCELLED}          (both terminal)

Host CAS primitive: CPython has no compare-exchange bytecode, so we build
consensus from the one atomic read-modify-write it does give us —
``list.append``.  Each cell keeps an append-only journal of *proposed*
transitions; folding the journal deterministically decides which proposals
won.  Append-only logs are a classic lock-free construction (every proposer
completes in a bounded number of steps; the journal is compacted by the
winner).  The serving engine and async checkpointer use these cells for
request lifecycle tracking.
"""
from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, Tuple

from repro.core import interleave as _il

# --- Request FSM (paper Figure 3) ------------------------------------------
REQUEST_FREE = "REQUEST_FREE"
REQUEST_VALID = "REQUEST_VALID"
REQUEST_RECEIVED = "REQUEST_RECEIVED"
REQUEST_COMPLETED = "REQUEST_COMPLETED"
REQUEST_CANCELLED = "REQUEST_CANCELLED"

REQUEST_TRANSITIONS: Dict[str, FrozenSet[str]] = {
    REQUEST_FREE: frozenset({REQUEST_VALID}),
    REQUEST_VALID: frozenset({REQUEST_RECEIVED, REQUEST_COMPLETED,
                              REQUEST_CANCELLED}),
    REQUEST_RECEIVED: frozenset({REQUEST_COMPLETED, REQUEST_CANCELLED}),
    REQUEST_COMPLETED: frozenset({REQUEST_FREE}),
    REQUEST_CANCELLED: frozenset({REQUEST_FREE}),
}

# --- Queue-entry / buffer FSM (paper Figure 4) ------------------------------
BUFFER_FREE = "BUFFER_FREE"
BUFFER_RESERVED = "BUFFER_RESERVED"
BUFFER_ALLOCATED = "BUFFER_ALLOCATED"
BUFFER_RECEIVED = "BUFFER_RECEIVED"
BUFFER_PREEMPTED = "BUFFER_PREEMPTED"

BUFFER_TRANSITIONS: Dict[str, FrozenSet[str]] = {
    BUFFER_FREE: frozenset({BUFFER_RESERVED}),
    # RESERVED -> FREE extends Figure 4 for chunked admission (DESIGN.md
    # §9): a slot whose prompt is still streaming in (pages claimed,
    # cache rows only partially materialized) can be aborted — client
    # cancel or mid-stream pool exhaustion — without ever having been
    # ALLOCATED.  The release is a single CAS, same as every other edge.
    BUFFER_RESERVED: frozenset({BUFFER_ALLOCATED, BUFFER_FREE}),
    # ALLOCATED -> PREEMPTED extends Figure 4 for overload control
    # (DESIGN.md §12): a decoding sequence's private KV pages are
    # swapped host-side and the cell parks until pages can be
    # re-claimed (PREEMPTED -> ALLOCATED, the resume) or the client
    # cancels it while parked (PREEMPTED -> FREE).  The cell travels
    # with the parked sequence, not the decode slot.
    BUFFER_ALLOCATED: frozenset({BUFFER_RECEIVED, BUFFER_PREEMPTED}),
    BUFFER_RECEIVED: frozenset({BUFFER_FREE}),
    BUFFER_PREEMPTED: frozenset({BUFFER_ALLOCATED, BUFFER_FREE}),
}

# --- Operation-handle FSM (MCAPI mcapi_test/mcapi_wait/mcapi_cancel) --------
OP_PENDING = "OP_PENDING"
OP_COMPLETED = "OP_COMPLETED"
OP_CANCELLED = "OP_CANCELLED"

OP_TRANSITIONS: Dict[str, FrozenSet[str]] = {
    OP_PENDING: frozenset({OP_COMPLETED, OP_CANCELLED}),
    OP_COMPLETED: frozenset(),          # terminal
    OP_CANCELLED: frozenset(),          # terminal
}


class IllegalTransition(RuntimeError):
    pass


_seq = itertools.count()  # itertools.count() is thread-safe in CPython


_COMPACT_AT = 64


class StateCell:
    """A lock-free CAS cell over a fixed transition table.

    ``cas(expected, new)`` returns True iff this caller's proposal is the one
    that moved the cell from ``expected`` to ``new``.  Multiple threads may
    race; exactly one wins per state occupancy.  Progress is lock-free: an
    append always completes, and deciding the winner is a pure fold.

    Representation: ``_journal`` is ONE append-only list of proposals
    ``[seq, expected, new, resolved]`` that is never replaced, so an
    append can never land in an abandoned list.  ``_base`` is an
    immutable pair ``(folded_state, folded_entries)`` stored as ONE
    attribute write; a fold starts from ``folded_state`` and replays
    every journal entry that is not (by identity) in ``folded_entries``.
    A fold is authoritative iff ``_base`` is unchanged when it finishes.

    Compaction (bounding the journal) is where two earlier versions of
    this cell had genuine lost-update races, both found by the
    deterministic interleaving checker:

    * The original two-store design (preserved as
      ``repro.checker.scenarios.LegacyStateCell``) wrote the folded base
      and the truncated journal as separate stores — a proposal folded
      between them replayed against a doubled history.
    * The second design swapped an immutable ``(base, journal[k:])``
      pair atomically, but the suffix copy and the swap were two steps:
      a proposal appended between them passed its currency check (the
      pair was still current), reported a WIN, and was then orphaned by
      the swap — schedule ``[1,1,1,1,1,1,0,0,0,0,0,1]`` of the
      ``statecell_compaction`` scenario loses a committed transition
      (the minimized schedule lives in ``tests/schedules/``).

    The watermark protocol here closes both windows:

      * a proposal is marked ``resolved`` only after its caller's
        authoritative fold, and the compactor folds ONLY the longest
        resolved prefix — it can never fold an entry whose owner has
        not yet seen the outcome (an unresolved entry also blocks every
        entry behind it, so position order is preserved);
      * the compactor installs ``(prefix_state, prefix_entries)`` as
        one atomic store and only THEN deletes the prefix from the
        journal (``del j[:k]`` — a single slice-delete).  Between the
        two, folds skip the prefix entries by identity, so both orders
        of the interim window read the same state.  ``_base`` keeps the
        folded entries alive, so their ids cannot be recycled while the
        skip set still matters;
      * a single-compactor guard (the ``setdefault`` CAS primitive)
        keeps rival compactions from interleaving; losers skip —
        compaction is opportunistic, so skipping is progress.
    """

    __slots__ = ("_table", "_base", "_journal", "_name", "_cguard",
                 "_compact_at")

    def __init__(self, table: Dict[str, FrozenSet[str]], initial: str,
                 name: str = "", compact_at: int = _COMPACT_AT):
        if initial not in table:
            raise ValueError(f"unknown state {initial!r}")
        self._table = table
        self._base: tuple = (initial, ())
        self._journal: list = []          # [[seq, expected, new, resolved]]
        self._name = name
        self._cguard: dict = {}
        self._compact_at = compact_at

    def _fold_once(self) -> Tuple[tuple, str, set]:
        """One fold pass: (base-read, folded state, winner seqs)."""
        base = self._base
        state = base[0]
        skip = {id(e) for e in base[1]}
        winners = set()
        for e in list(self._journal):
            if id(e) in skip:             # already folded into the base
                continue
            if e[1] == state and e[2] in self._table[state]:
                state = e[2]
                winners.add(e[0])
        return base, state, winners

    def _fold_current(self) -> Tuple[str, set]:
        """Fold base + journal; retry if a compaction moved the base
        mid-fold (our journal snapshot may then miss folded entries
        whose effect the stale base did not carry)."""
        while True:
            if _il._active is not None:
                _il._active.yield_point("states.fold", id(self))
            base, state, winners = self._fold_once()
            if _il._active is not None:
                _il._active.yield_point("states.fold.verify", id(self))
            if self._base is base:
                return state, winners

    @property
    def state(self) -> str:
        return self._fold_current()[0]

    def cas(self, expected: str, new: str) -> bool:
        if new not in self._table.get(expected, frozenset()):
            raise IllegalTransition(
                f"{self._name}: {expected} -> {new} not in transition table")
        seq = next(_seq)
        entry = [seq, expected, new, False]
        if _il._active is not None:
            _il._active.yield_point("states.append", id(self))
        self._journal.append(entry)       # atomic append = consensus order
        # Our own entry is unresolved, so no compactor can fold or delete
        # it before the authoritative fold below returns its verdict.
        won = entry[0] in self._fold_current()[1]
        if _il._active is not None:
            _il._active.yield_point("states.resolve", id(self))
        entry[3] = True                   # compactable from here on
        if len(self._journal) > self._compact_at:
            self._maybe_compact()
        return won

    def _maybe_compact(self) -> None:
        """Fold the longest resolved journal prefix into the base with
        one atomic store, then drop the prefix — opportunistic,
        single-compactor, and unable to touch an unresolved (in-flight)
        proposal by construction."""
        tok = object()
        if _il._active is not None:
            _il._active.yield_point("states.compact.guard", id(self))
        if self._cguard.setdefault("c", tok) is not tok:
            return                        # a rival compactor is active
        try:
            j = self._journal
            k = 0
            while k < len(j) and j[k][3]:
                k += 1
            if k == 0:
                return
            prefix = tuple(j[:k])
            base = self._base             # stable: we hold the guard
            state = base[0]
            skip = {id(e) for e in base[1]}
            for e in prefix:
                if id(e) in skip:         # defensive; prior del precedes
                    continue              # guard release, so never hit
                if e[1] == state and e[2] in self._table[state]:
                    state = e[2]
            if _il._active is not None:
                _il._active.yield_point("states.compact.swap", id(self))
            self._base = (state, prefix)  # ONE atomic store installs both
            if _il._active is not None:
                _il._active.yield_point("states.compact.del", id(self))
            del j[:k]                     # cleanup; folds skip by identity
        finally:
            self._cguard.pop("c", None)

    def transition(self, expected: str, new: str) -> None:
        if not self.cas(expected, new):
            raise IllegalTransition(
                f"{self._name}: lost CAS {expected} -> {new} "
                f"(actual state {self.state})")

    # -- pickling (crash-recovery snapshots) --------------------------------
    # A cell serializes as its folded state plus the NAME of its transition
    # table: the journal is history, not state, so it compacts to nothing,
    # and unpickling rebinds the canonical module-level table object (table
    # identity matters — a deep-copied table would defeat `is` comparisons
    # and bloat every snapshot with the same frozen dict).

    def __getstate__(self):
        table_name = _TABLE_NAMES.get(id(self._table))
        if table_name is None:
            raise TypeError(
                f"{self._name}: cannot pickle a StateCell over a "
                f"non-canonical transition table")
        return (table_name, self.state, self._name)

    def __setstate__(self, state):
        table_name, folded, name = state
        self._table = _TABLES[table_name]
        self._base = (folded, ())
        self._journal = []
        self._name = name
        self._cguard = {}
        self._compact_at = _COMPACT_AT


_TABLES: Dict[str, Dict[str, FrozenSet[str]]] = {
    "REQUEST": REQUEST_TRANSITIONS,
    "BUFFER": BUFFER_TRANSITIONS,
    "OP": OP_TRANSITIONS,
}
_TABLE_NAMES = {id(t): n for n, t in _TABLES.items()}


def request_cell(name: str = "request") -> StateCell:
    return StateCell(REQUEST_TRANSITIONS, REQUEST_FREE, name)


def buffer_cell(name: str = "buffer") -> StateCell:
    return StateCell(BUFFER_TRANSITIONS, BUFFER_FREE, name)


def op_cell(name: str = "op") -> StateCell:
    return StateCell(OP_TRANSITIONS, OP_PENDING, name)
