"""Non-Blocking Buffer (NBB) — lock-free event-message FIFO ring buffer.

Faithful implementation of the algorithm the paper adopts from
Kim, Colmenares & Rim, "Efficient adaptations of the non-blocking buffer for
event message communication" (ISORC 2007), as refactored into MCAPI by
Harper & de Gooijer (2014), Section 3.

Two atomic counters guard disjoint sections of a circular ring buffer:

  * ``update_count`` (UC)      — owned by the single producer,
  * ``acknowledge_count`` (AC) — owned by the single consumer.

Each counter is incremented *twice* per operation: once before the slot
access starts and once after it completes, so an odd value means an
operation is in flight.  Items in the buffer = UC//2 - AC//2.  Producer and
consumer always address different slots, hence neither ever blocks the
other; operations that cannot proceed return one of the four status codes of
the paper's Table 1 instead of waiting.

Two variants are provided:

  * :class:`HostNBB` — a real lock-free SPSC queue for host-side Python
    threads (data pipeline -> trainer, request batcher -> serving engine).
    Under CPython, aligned int stores/loads and single-slot list assignment
    are atomic, so the single-writer-per-counter discipline is sound.
  * Functional JAX form (:func:`init`, :func:`insert_item`,
    :func:`read_item`) — the same state machine expressed as a pure function
    over an :class:`NBBState` pytree so it can live inside ``jit`` /
    ``lax.scan`` loops.  This is the synchronization skeleton used by the
    ring-buffered pipeline-parallel schedule in
    ``repro.parallel.pipeline``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import interleave as _il

# Deterministic-interleaving yield points (repro.core.interleave): each
# shared-memory access below is preceded by one `_il._active is None`
# check — the same zero-overhead-unarmed contract as core/faults.py
# sites.  Armed, the access parks the task and the VirtualScheduler
# decides who advances, which is what lets the checker enumerate every
# counter/slot interleaving of this protocol.

# ---------------------------------------------------------------------------
# Status codes — Table 1 of the paper.
# ---------------------------------------------------------------------------
OK = 0
BUFFER_FULL = 1                          # caller should yield and retry later
BUFFER_FULL_BUT_CONSUMER_READING = 2     # retry immediately, bounded spins
BUFFER_EMPTY = 3                         # caller should yield and retry later
BUFFER_EMPTY_BUT_PRODUCER_INSERTING = 4  # retry immediately, bounded spins

STATUS_NAMES = {
    OK: "OK",
    BUFFER_FULL: "BUFFER_FULL",
    BUFFER_FULL_BUT_CONSUMER_READING: "BUFFER_FULL_BUT_CONSUMER_READING",
    BUFFER_EMPTY: "BUFFER_EMPTY",
    BUFFER_EMPTY_BUT_PRODUCER_INSERTING: "BUFFER_EMPTY_BUT_PRODUCER_INSERTING",
}


# ---------------------------------------------------------------------------
# Host (threaded) variant — genuine lock-free SPSC ring for CPython threads.
# ---------------------------------------------------------------------------
class HostNBB:
    """Single-producer single-consumer non-blocking buffer for host threads.

    ``insert_item`` may only ever be called from one thread, ``read_item``
    from one (possibly different) thread.  No locks anywhere: the producer is
    the sole writer of ``_uc`` and of the slot it addresses; the consumer is
    the sole writer of ``_ac``.  CPython guarantees the individual loads and
    stores are atomic, which is exactly the memory model the paper's
    PowerPC/x86 discussion (Section 3) relies on.
    """

    __slots__ = ("_n", "_slots", "_uc", "_ac")

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("NBB capacity must be >= 1")
        self._n = capacity
        self._slots: list = [None] * capacity
        self._uc = 0  # update counter (producer-owned)
        self._ac = 0  # acknowledge counter (consumer-owned)

    @property
    def capacity(self) -> int:
        return self._n

    def __len__(self) -> int:  # snapshot; may be stale under concurrency
        return (self._uc // 2) - (self._ac // 2)

    def insert_item(self, item: Any) -> int:
        if _il._active is not None:
            _il._active.yield_point("nbb.send.load", id(self))
        uc = self._uc
        ac = self._ac  # single racy read — fine: AC only grows
        if (uc // 2) - (ac // 2) >= self._n:
            # Full.  Distinguish "consumer mid-read" (spin briefly) from
            # "consumer idle" (yield) exactly as the paper's Table 1.
            if ac & 1:
                return BUFFER_FULL_BUT_CONSUMER_READING
            return BUFFER_FULL
        if _il._active is not None:
            _il._active.yield_point("nbb.send.announce", id(self))
        self._uc = uc + 1                       # announce write-in-progress
        if _il._active is not None:
            _il._active.yield_point("nbb.send.slot",
                                    (id(self), (uc // 2) % self._n))
        self._slots[(uc // 2) % self._n] = item
        if _il._active is not None:
            _il._active.yield_point("nbb.send.commit", id(self))
        self._uc = uc + 2                       # commit
        return OK

    def read_item(self) -> Tuple[int, Optional[Any]]:
        if _il._active is not None:
            _il._active.yield_point("nbb.recv.load", id(self))
        ac = self._ac
        uc = self._uc  # single racy read — UC only grows
        if (uc // 2) == (ac // 2):
            if uc & 1:
                return BUFFER_EMPTY_BUT_PRODUCER_INSERTING, None
            return BUFFER_EMPTY, None
        if _il._active is not None:
            _il._active.yield_point("nbb.recv.announce", id(self))
        self._ac = ac + 1                       # announce read-in-progress
        idx = (ac // 2) % self._n
        if _il._active is not None:
            _il._active.yield_point("nbb.recv.slot", (id(self), idx))
        item = self._slots[idx]
        self._slots[idx] = None                 # help GC; slot now ours alone
        if _il._active is not None:
            _il._active.yield_point("nbb.recv.ack", id(self))
        self._ac = ac + 2                       # acknowledge
        return OK, item

    # -- packet-mode burst operations (paper Tables 5-7) ---------------------
    # One counter announce/commit pair moves a whole contiguous span, so a
    # K-item block costs one ring exchange instead of K scalar exchanges.
    # Safety is unchanged: the span only becomes visible to the peer at the
    # single commit store, and the peer cannot enter the span before it
    # (mid-burst, the odd counter reads as the Table-1 transient status).
    def send_burst(self, vals) -> Tuple[int, int]:
        """Producer-side packet insert of ``vals`` (a sequence).

        Reserves the longest prefix that fits and copies it with at most
        two slice assignments (wrap-around).  Returns ``(status, n)``
        where ``n`` items were enqueued: OK iff every item fit, else the
        Table-1 full status with ``n`` possibly 0 (full-ring refusal) —
        all-at-once visibility either way.
        """
        want = len(vals)
        if _il._active is not None:
            _il._active.yield_point("nbb.burst.load", id(self))
        uc = self._uc
        ac = self._ac  # single racy read — fine: AC only grows
        space = self._n - ((uc // 2) - (ac // 2))
        full = (BUFFER_FULL_BUT_CONSUMER_READING if ac & 1 else BUFFER_FULL)
        if want == 0:
            return OK, 0
        if space <= 0:
            return full, 0
        m = min(space, want)
        if _il._active is not None:
            _il._active.yield_point("nbb.burst.announce", id(self))
        self._uc = uc + 1                       # announce burst-in-progress
        start = (uc // 2) % self._n
        head = min(m, self._n - start)
        if _il._active is not None:
            _il._active.yield_point("nbb.burst.copy",
                                    (id(self), start, m, self._n))
        self._slots[start:start + head] = vals[:head]
        if m > head:                            # wrap-around: second slice
            self._slots[:m - head] = vals[head:m]
        if _il._active is not None:
            _il._active.yield_point("nbb.burst.commit", id(self))
        self._uc = uc + 2 * m                   # commit the whole span
        return (OK, m) if m == want else (full, m)

    def drain_burst(self, max_n: Optional[int] = None) -> list:
        """Consumer-side packet read: everything available now (bounded
        by ``max_n``), one announce/ack counter pair, at most two slice
        copies.  Empty list when nothing is committed."""
        if _il._active is not None:
            _il._active.yield_point("nbb.drain.load", id(self))
        ac = self._ac
        uc = self._uc  # single racy read — UC only grows
        avail = (uc // 2) - (ac // 2)
        if avail <= 0:
            return []
        m = avail if max_n is None else min(avail, max_n)
        if m <= 0:
            return []
        if _il._active is not None:
            _il._active.yield_point("nbb.drain.announce", id(self))
        self._ac = ac + 1                       # announce read-in-progress
        start = (ac // 2) % self._n
        head = min(m, self._n - start)
        if _il._active is not None:
            _il._active.yield_point("nbb.drain.copy",
                                    (id(self), start, m, self._n))
        out = self._slots[start:start + head]
        self._slots[start:start + head] = [None] * head     # help GC
        if m > head:
            out += self._slots[:m - head]
            self._slots[:m - head] = [None] * (m - head)
        if _il._active is not None:
            _il._active.yield_point("nbb.drain.ack", id(self))
        self._ac = ac + 2 * m                   # acknowledge the span
        return out

    # -- Transport protocol (repro.core.transport) ---------------------------
    # insert/read already speak Table-1 statuses; the aliases make HostNBB a
    # structural Transport so channels/engines need no per-type dispatch.
    send = insert_item
    try_recv = read_item

    def send_i(self, payload: Any):
        """Non-blocking send returning an OpHandle (mcapi_msg_send_i)."""
        from repro.core import transport  # late: transport imports this module
        return transport.send_i(self, payload)

    def recv_i(self):
        """Non-blocking receive returning an OpHandle (mcapi_msg_recv_i)."""
        from repro.core import transport
        return transport.recv_i(self)

    def drain(self, max_items: Optional[int] = None) -> list:
        """Consumer-side: take every item available now (non-blocking)."""
        out = []
        while max_items is None or len(out) < max_items:
            status, item = self.read_item()
            if status != OK:
                break
            out.append(item)
        return out

    # Convenience blocking wrappers.  Both route through the Table-1
    # Backoff discipline (spin on transient, yield, then exponential
    # sleep — never a raw `sleep(0)` burn) and take an optional
    # deadline: a dead peer bounds the caller's wait instead of
    # spinning it forever outside the serve loop's watchdog.
    def put(self, item: Any, timeout_s: Optional[float] = None,
            backoff: Optional[Any] = None) -> bool:
        """Blocking insert.  True when delivered; False on deadline
        (``timeout_s``) with the item NOT enqueued."""
        from repro.core import transport  # late: transport imports this module
        b = backoff if backoff is not None else transport.Backoff()
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        while True:
            st = self.insert_item(item)
            if st == OK:
                return True
            if deadline is not None and time.monotonic() > deadline:
                return False
            b.wait(st)

    def get(self, timeout_s: Optional[float] = None,
            backoff: Optional[Any] = None) -> Any:
        """Blocking read.  Returns the item; raises ``TimeoutError`` on
        deadline (``timeout_s``) so an absent producer cannot park the
        caller forever."""
        from repro.core import transport
        b = backoff if backoff is not None else transport.Backoff()
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        while True:
            st, item = self.read_item()
            if st == OK:
                return item
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"NBB get(): no item within {timeout_s}s "
                    f"(last status {STATUS_NAMES[st]})")
            b.wait(st)


# ---------------------------------------------------------------------------
# Functional JAX variant.
# ---------------------------------------------------------------------------
class NBBState(NamedTuple):
    """Pure-functional NBB state (a pytree, usable as scan carry)."""

    update_count: jnp.ndarray       # i32 scalar, producer counter
    acknowledge_count: jnp.ndarray  # i32 scalar, consumer counter
    slots: jnp.ndarray              # [capacity, *item_shape]


def init(capacity: int, item: jax.ShapeDtypeStruct | jnp.ndarray) -> NBBState:
    """Create an empty NBB holding ``capacity`` items shaped like ``item``."""
    shape = tuple(item.shape)
    dtype = item.dtype
    return NBBState(
        update_count=jnp.zeros((), jnp.int32),
        acknowledge_count=jnp.zeros((), jnp.int32),
        slots=jnp.zeros((capacity,) + shape, dtype),
    )


def size(state: NBBState) -> jnp.ndarray:
    return state.update_count // 2 - state.acknowledge_count // 2


def insert_item(state: NBBState, item: jnp.ndarray) -> Tuple[NBBState, jnp.ndarray]:
    """Producer op.  Returns (new_state, status).  Never blocks: when the ring
    is full the state is returned unchanged with a BUFFER_FULL* status."""
    n = state.slots.shape[0]
    uc, ac = state.update_count, state.acknowledge_count
    full = (uc // 2 - ac // 2) >= n
    status = jnp.where(
        full,
        jnp.where(ac % 2 == 1,
                  jnp.int32(BUFFER_FULL_BUT_CONSUMER_READING),
                  jnp.int32(BUFFER_FULL)),
        jnp.int32(OK),
    )
    idx = (uc // 2) % n
    new_slots = jnp.where(
        full,
        state.slots,
        state.slots.at[idx].set(item.astype(state.slots.dtype)),
    )
    new_uc = jnp.where(full, uc, uc + 2)  # both half-increments fused: the
    # functional update is atomic by construction (no observer between them).
    return NBBState(new_uc, ac, new_slots), status


def read_item(state: NBBState) -> Tuple[NBBState, jnp.ndarray, jnp.ndarray]:
    """Consumer op.  Returns (new_state, item, status); ``item`` is zeros when
    status != OK (callers must branch on status, as in the paper)."""
    n = state.slots.shape[0]
    uc, ac = state.update_count, state.acknowledge_count
    empty = (uc // 2) == (ac // 2)
    status = jnp.where(
        empty,
        jnp.where(uc % 2 == 1,
                  jnp.int32(BUFFER_EMPTY_BUT_PRODUCER_INSERTING),
                  jnp.int32(BUFFER_EMPTY)),
        jnp.int32(OK),
    )
    idx = (ac // 2) % n
    item = jnp.where(empty, jnp.zeros_like(state.slots[0]), state.slots[idx])
    new_ac = jnp.where(empty, ac, ac + 2)
    return NBBState(uc, new_ac, state.slots), item, status


# ---------------------------------------------------------------------------
# Interleaving simulator — used by property tests to exercise the *torn*
# (odd-counter) states that the fused functional ops above never expose.
# It executes half-increments as separate micro-ops under an arbitrary
# producer/consumer interleaving, which is how we check the paper's Safety
# property (a successful read never observes a partially-written slot).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SimNBB:
    capacity: int

    def __post_init__(self):
        self.uc = 0
        self.ac = 0
        self.slots = [(0, 0)] * self.capacity  # (value, torn_flag)

    # Producer micro-ops -----------------------------------------------------
    def try_begin_insert(self) -> int:
        if (self.uc // 2) - (self.ac // 2) >= self.capacity:
            return (BUFFER_FULL_BUT_CONSUMER_READING
                    if self.ac % 2 else BUFFER_FULL)
        self.uc += 1
        return OK

    def write_half(self, value):
        """First half of a non-atomic multi-word write: slot is torn."""
        self.slots[(self.uc // 2) % self.capacity] = (value, 1)

    def write_commit(self, value):
        self.slots[(self.uc // 2) % self.capacity] = (value, 0)
        self.uc += 1

    # Consumer micro-ops -----------------------------------------------------
    def try_begin_read(self) -> int:
        if (self.uc // 2) == (self.ac // 2):
            return (BUFFER_EMPTY_BUT_PRODUCER_INSERTING
                    if self.uc % 2 else BUFFER_EMPTY)
        self.ac += 1
        return OK

    def read_commit(self):
        value, torn = self.slots[(self.ac // 2) % self.capacity]
        self.ac += 1
        return value, torn
