"""MCAPI-style communication API: domains, nodes, endpoints, channels.

Reproduces the MCAPI surface the paper refactors (Section 2): three
communication formats over FIFO delivery —

  1) MESSAGES — connection-less, ad-hoc endpoints,
  2) PACKETS  — connection-oriented over established FIFO channels,
  3) SCALARS  — connection-oriented 8/16/32/64-bit values,

backed here by lock-free NBB rings (the paper's refactored design) or by the
mutex-guarded baseline (the reference design) for A/B benchmarking.

The same endpoint naming scheme is reused at the *device* level:
:class:`DeviceChannel` describes a point-to-point edge on a mesh axis and
resolves to a ``jax.lax.ppermute`` partner list — the TPU analogue of an
MCAPI FIFO channel, with ICI playing the role of the shared-memory bus
(DESIGN.md §2).  ``repro.parallel.pipeline`` builds its ring schedule from
these descriptors.
"""
from __future__ import annotations

import dataclasses
import enum
import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import nbb, nbw
from repro.core.host_queue import LockedQueue, SpscQueue


class ChannelType(enum.Enum):
    MESSAGE = "message"   # connection-less, priority FIFO
    PACKET = "packet"     # connected, buffer handoff
    SCALAR = "scalar"     # connected, 8..64-bit values
    STATE = "state"       # NBW: freshest-value, order-indeterminate
    # STATE implements the paper's §7 future work: "enhance the MCAPI
    # runtime to support state message data exchange policies ... we
    # expect to see a speed-up because it drops the FIFO requirement."
    # The writer can never block or fill the channel (NBW non-blocking
    # property); the reader always sees the newest committed value.
    # benchmarks/bench_lockfree.py state_vs_fifo() measures the
    # predicted speed-up.


class Endpoint:
    """An addressable port owned by a node (MCAPI <domain, node, port>)."""

    def __init__(self, domain: int, node: int, port: int):
        self.address = (domain, node, port)
        self.rx: Optional[Any] = None   # receive queue, set when connected

    def __repr__(self):
        return f"Endpoint{self.address}"


@dataclasses.dataclass
class Channel:
    """A one-way FIFO connection between two endpoints."""

    ctype: ChannelType
    send_ep: Endpoint
    recv_ep: Endpoint
    queue: Any  # SpscQueue (lock-free) or LockedQueue (baseline)

    def send(self, payload: Any) -> int:
        if self.ctype is ChannelType.STATE:
            self.queue.write(payload)      # NBW: never blocks, never full
            return nbb.OK
        if self.ctype is ChannelType.SCALAR:
            payload = _pack_scalar(payload)
        return self.queue.insert_item(payload)

    def recv(self) -> Tuple[int, Optional[Any]]:
        if self.ctype is ChannelType.STATE:
            status, payload = self.queue.try_read()
            if status != nbw.OK:
                return nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING, None
            if payload is None:            # nothing published yet
                return nbb.BUFFER_EMPTY, None
            return nbb.OK, payload
        status, payload = self.queue.read_item()
        if status == nbb.OK and self.ctype is ChannelType.SCALAR:
            payload = _unpack_scalar(payload)
        return status, payload

    def send_blocking(self, payload: Any) -> None:
        import time
        while self.send(payload) != nbb.OK:
            time.sleep(0)

    def recv_blocking(self) -> Any:
        import time
        while True:
            status, payload = self.recv()
            if status == nbb.OK:
                return payload
            time.sleep(0)


def _pack_scalar(value: int) -> bytes:
    # MCAPI scalars are 8/16/32/64-bit; we carry them as 8 bytes.
    return struct.pack("<q", int(value))


def _unpack_scalar(b: bytes) -> int:
    return struct.unpack("<q", b)[0]


class Domain:
    """A communication domain: creates endpoints and connects channels."""

    def __init__(self, domain_id: int = 0, lock_free: bool = True,
                 queue_capacity: int = 64):
        self.domain_id = domain_id
        self.lock_free = lock_free
        self.queue_capacity = queue_capacity
        self._endpoints: Dict[Tuple[int, int, int], Endpoint] = {}
        self.channels: List[Channel] = []

    def create_endpoint(self, node: int, port: int) -> Endpoint:
        key = (self.domain_id, node, port)
        if key in self._endpoints:
            raise ValueError(f"endpoint {key} already exists")
        ep = Endpoint(*key)
        self._endpoints[key] = ep
        return ep

    def connect(self, ctype: ChannelType, send_ep: Endpoint,
                recv_ep: Endpoint, nbw_depth: int = 4) -> Channel:
        if ctype is ChannelType.STATE:
            queue: Any = nbw.HostNBW(depth=nbw_depth)
        elif self.lock_free:
            queue = SpscQueue(self.queue_capacity)
        else:
            queue = LockedQueue(self.queue_capacity)
        ch = Channel(ctype, send_ep, recv_ep, queue)
        recv_ep.rx = queue
        self.channels.append(ch)
        return ch


# ---------------------------------------------------------------------------
# Device-level channels: FIFO edges over a mesh axis.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceChannel:
    """A point-to-point ring edge along a named mesh axis.

    ``perm(n)`` yields the (source, dest) pairs for ``jax.lax.ppermute`` —
    every member sends to its ``+shift`` neighbour, the device analogue of an
    MCAPI FIFO channel between adjacent cores.
    """

    axis: str
    shift: int = 1

    def perm(self, axis_size: int) -> List[Tuple[int, int]]:
        return [(i, (i + self.shift) % axis_size) for i in range(axis_size)]

    def reverse(self) -> "DeviceChannel":
        return DeviceChannel(self.axis, -self.shift)
