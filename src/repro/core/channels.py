"""MCAPI-style communication API: domains, nodes, endpoints, channels.

Reproduces the MCAPI surface the paper refactors (Section 2): three
communication formats over FIFO delivery —

  1) MESSAGES — connection-less, ad-hoc endpoints,
  2) PACKETS  — connection-oriented over established FIFO channels,
  3) SCALARS  — connection-oriented 8/16/32/64-bit values,

backed here by lock-free NBB rings (the paper's refactored design) or by the
mutex-guarded baseline (the reference design) for A/B benchmarking.

The same endpoint naming scheme is reused at the *device* level:
:class:`DeviceChannel` describes a point-to-point edge on a mesh axis and
resolves to a ``jax.lax.ppermute`` partner list — the TPU analogue of an
MCAPI FIFO channel, with ICI playing the role of the shared-memory bus
(DESIGN.md §2).  ``repro.parallel.pipeline`` builds its ring schedule from
these descriptors.
"""
from __future__ import annotations

import dataclasses
import enum
import struct
from typing import Any, Dict, List, Optional, Tuple

from repro.core import nbw, transport
from repro.core.host_queue import LockedQueue, SpscQueue
from repro.core.transport import (CodecTransport, PriorityTransport,
                                  StateTransport, Transport)


class ChannelType(enum.Enum):
    # MESSAGE delivery is priority FIFO: ``msg_send(payload, priority)``
    # targets one of ``Domain.msg_priorities`` per-class rings (0 = most
    # urgent) and the receiver always serves the lowest-numbered
    # nonempty class first, FIFO within a class (PriorityTransport).
    # Unprioritized ``send`` lands in the least urgent class.
    MESSAGE = "message"   # connection-less, priority FIFO
    PACKET = "packet"     # connected, buffer handoff
    SCALAR = "scalar"     # connected, 8..64-bit values
    STATE = "state"       # NBW: freshest-value, order-indeterminate
    # STATE implements the paper's §7 future work: "enhance the MCAPI
    # runtime to support state message data exchange policies ... we
    # expect to see a speed-up because it drops the FIFO requirement."
    # The writer can never block or fill the channel (NBW non-blocking
    # property); the reader always sees the newest committed value.
    # benchmarks/bench_lockfree.py state_vs_fifo() measures the
    # predicted speed-up.


class Endpoint:
    """An addressable port owned by a node (MCAPI <domain, node, port>)."""

    def __init__(self, domain: int, node: int, port: int):
        self.address = (domain, node, port)
        self.rx: Optional[Any] = None   # receive queue, set when connected

    def __repr__(self):
        return f"Endpoint{self.address}"


@dataclasses.dataclass
class Channel:
    """A one-way connection between two endpoints.

    Every channel type speaks through one :class:`Transport`: the format
    differences (scalar packing, NBW state semantics) are baked into the
    transport stack at :meth:`Domain.connect` time, so send/recv here are
    pure delegation — no per-``ChannelType`` dispatch on the hot path.
    """

    ctype: ChannelType
    send_ep: Endpoint
    recv_ep: Endpoint
    transport: Transport
    queue: Any  # underlying ring/cell (introspection + benchmarks)

    def send(self, payload: Any) -> int:
        return self.transport.send(payload)

    def recv(self) -> Tuple[int, Optional[Any]]:
        return self.transport.try_recv()

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return self.transport.drain(max_items)

    # -- packet-mode bursts (paper Tables 5-7): one exchange per block -----
    def send_burst(self, vals) -> Tuple[int, int]:
        return self.transport.send_burst(vals)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        return self.transport.drain_burst(max_n)

    def pkt_send_burst(self, vals) -> Tuple[int, int]:
        """Packet-channel burst — the batched exchange that MCAPI packet
        mode exists for; format-enforced like the other ``pkt_*`` ops."""
        self._require(ChannelType.PACKET, "pkt_send_burst")
        return self.send_burst(vals)

    def pkt_drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        self._require(ChannelType.PACKET, "pkt_drain_burst")
        return self.drain_burst(max_n)

    # -- non-blocking operation handles (MCAPI ``*_i`` variants) -----------
    # send_i/recv_i work on any channel type; the MCAPI-named variants
    # enforce the connection format they are defined for (calling a
    # packet-channel op on a scalar channel is an API error in MCAPI).
    def send_i(self, payload: Any) -> transport.OpHandle:
        return transport.send_i(self.transport, payload)

    def recv_i(self) -> transport.OpHandle:
        return transport.recv_i(self.transport)

    def _require(self, ctype: "ChannelType", op: str) -> None:
        if self.ctype is not ctype:
            raise ValueError(f"{op} on a {self.ctype.value} channel "
                             f"(needs {ctype.value})")

    def msg_send(self, payload: Any,
                 priority: Optional[int] = None) -> int:
        """MESSAGE send with an MCAPI-style priority class (0 = most
        urgent; None = the channel's default, least urgent).  The
        receiver drains classes strict-priority, FIFO within a class —
        the "priority FIFO" the MESSAGE format documents."""
        self._require(ChannelType.MESSAGE, "msg_send")
        if priority is None:
            return self.transport.send(payload)
        return self.transport.send_to(payload, priority)

    def msg_send_i(self, payload: Any,
                   priority: Optional[int] = None) -> transport.OpHandle:
        self._require(ChannelType.MESSAGE, "msg_send_i")
        if priority is None:
            return self.send_i(payload)
        h = transport.OpHandle(
            lambda: (self.transport.send_to(payload, priority), None),
            name="msg_send_i")
        h.test()
        return h

    def msg_recv_i(self) -> transport.OpHandle:
        self._require(ChannelType.MESSAGE, "msg_recv_i")
        return self.recv_i()

    def pkt_send_i(self, payload: Any) -> transport.OpHandle:
        self._require(ChannelType.PACKET, "pkt_send_i")
        return self.send_i(payload)

    def pkt_recv_i(self) -> transport.OpHandle:
        self._require(ChannelType.PACKET, "pkt_recv_i")
        return self.recv_i()

    def scalar_send_i(self, value: int) -> transport.OpHandle:
        self._require(ChannelType.SCALAR, "scalar_send_i")
        return self.send_i(value)

    def scalar_recv_i(self) -> transport.OpHandle:
        self._require(ChannelType.SCALAR, "scalar_recv_i")
        return self.recv_i()

    # -- blocking calls: thin wrappers over handle + wait ------------------
    def send_blocking(self, payload: Any,
                      timeout_s: Optional[float] = None) -> bool:
        return self.send_i(payload).wait(timeout_s=timeout_s)

    def recv_blocking(self, timeout_s: Optional[float] = None) -> Any:
        h = self.recv_i()
        if not h.wait(timeout_s=timeout_s):
            raise TimeoutError("recv_blocking timed out")
        return h.result


def _pack_scalar(value: int) -> bytes:
    # MCAPI scalars are 8/16/32/64-bit; we carry them as 8 bytes.
    return struct.pack("<q", int(value))


def _unpack_scalar(b: bytes) -> int:
    return struct.unpack("<q", b)[0]


class Domain:
    """A communication domain: creates endpoints and connects channels."""

    def __init__(self, domain_id: int = 0, lock_free: bool = True,
                 queue_capacity: int = 64, msg_priorities: int = 4):
        if msg_priorities < 1:
            raise ValueError("need msg_priorities >= 1")
        self.domain_id = domain_id
        self.lock_free = lock_free
        self.queue_capacity = queue_capacity
        self.msg_priorities = msg_priorities
        self._endpoints: Dict[Tuple[int, int, int], Endpoint] = {}
        self.channels: List[Channel] = []

    def create_endpoint(self, node: int, port: int) -> Endpoint:
        key = (self.domain_id, node, port)
        if key in self._endpoints:
            raise ValueError(f"endpoint {key} already exists")
        ep = Endpoint(*key)
        self._endpoints[key] = ep
        return ep

    def connect(self, ctype: ChannelType, send_ep: Endpoint,
                recv_ep: Endpoint, nbw_depth: int = 4) -> Channel:
        """Build the transport stack for this channel type, once.

        Type dispatch happens HERE (connection setup), never per-op:
        STATE gets an NBW cell behind a :class:`StateTransport`; SCALAR
        wraps the ring in a packing :class:`CodecTransport`; MESSAGE
        gets ``msg_priorities`` per-class rings behind a
        :class:`PriorityTransport` (priority FIFO delivery); PACKET
        rides the raw ring, which is already a Transport.
        """
        if ctype is ChannelType.STATE:
            queue: Any = nbw.HostNBW(depth=nbw_depth)
            tp: Transport = StateTransport(queue)
        elif ctype is ChannelType.MESSAGE:
            rings = [SpscQueue(self.queue_capacity) if self.lock_free
                     else LockedQueue(self.queue_capacity)
                     for _ in range(self.msg_priorities)]
            tp = PriorityTransport(rings)
            queue = tp
        else:
            queue = (SpscQueue(self.queue_capacity) if self.lock_free
                     else LockedQueue(self.queue_capacity))
            tp = (CodecTransport(queue, _pack_scalar, _unpack_scalar)
                  if ctype is ChannelType.SCALAR else queue)
        ch = Channel(ctype, send_ep, recv_ep, tp, queue)
        recv_ep.rx = tp
        self.channels.append(ch)
        return ch


# ---------------------------------------------------------------------------
# Device-level channels: FIFO edges over a mesh axis.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceChannel:
    """A point-to-point ring edge along a named mesh axis.

    ``perm(n)`` yields the (source, dest) pairs for ``jax.lax.ppermute`` —
    every member sends to its ``+shift`` neighbour, the device analogue of an
    MCAPI FIFO channel between adjacent cores.
    """

    axis: str
    shift: int = 1

    def perm(self, axis_size: int) -> List[Tuple[int, int]]:
        return [(i, (i + self.shift) % axis_size) for i in range(axis_size)]

    def reverse(self) -> "DeviceChannel":
        return DeviceChannel(self.axis, -self.shift)
