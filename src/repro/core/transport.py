"""Unified lock-free Transport protocol — one wire format for every queue.

Every host-side communication primitive in this repo (SPSC/MPSC NBB rings,
the mutex baseline, NBW state cells, MCAPI channels) exposes the same
three operations:

  send(payload) -> status            non-blocking insert/publish
  try_recv()    -> (status, payload) non-blocking read
  drain(max_items) -> [payload, ..]  take everything available *now*

with the paper's Table-1 status codes (``repro.core.nbb``):

  OK                                   operation committed
  BUFFER_FULL                          stable:    yield, retry later
  BUFFER_FULL_BUT_CONSUMER_READING     transient: spin, retry immediately
  BUFFER_EMPTY                         stable:    yield, retry later
  BUFFER_EMPTY_BUT_PRODUCER_INSERTING  transient: spin, retry immediately

The split into *stable* and *transient* failures is the paper's retry
discipline: a transient status means the peer is mid-operation (an odd
counter) and will commit within a bounded number of instructions, so the
caller should busy-retry; a stable status means progress depends on the
peer being scheduled at all, so the caller should yield — and, if the
condition persists, back off exponentially rather than burn the core.
:class:`Backoff` packages that policy; :func:`send_blocking` /
:func:`recv_blocking` are the canonical retry loops built on it.

STATE (NBW) cells join the protocol through :class:`StateTransport`,
which maps the NBW collision statuses onto Table 1 (a collision *is*
"producer inserting").  Scalar channels wrap any transport in a
:class:`CodecTransport` so the packing happens in the transport stack,
not in per-call ``ChannelType`` dispatch (see DESIGN.md §3).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Protocol, Tuple, runtime_checkable

from repro.core import nbb, nbw

# Table-1 status codes, re-exported so transport users need one import.
OK = nbb.OK
BUFFER_FULL = nbb.BUFFER_FULL
BUFFER_FULL_BUT_CONSUMER_READING = nbb.BUFFER_FULL_BUT_CONSUMER_READING
BUFFER_EMPTY = nbb.BUFFER_EMPTY
BUFFER_EMPTY_BUT_PRODUCER_INSERTING = nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING

#: Statuses where the peer is mid-operation: retry immediately (spin).
TRANSIENT = frozenset({BUFFER_FULL_BUT_CONSUMER_READING,
                       BUFFER_EMPTY_BUT_PRODUCER_INSERTING})


@runtime_checkable
class Transport(Protocol):
    """Anything that moves payloads with Table-1 status codes."""

    def send(self, payload: Any) -> int: ...

    def try_recv(self) -> Tuple[int, Optional[Any]]: ...

    def drain(self, max_items: Optional[int] = None) -> List[Any]: ...


class Backoff:
    """Bounded exponential backoff implementing the Table-1 retry discipline.

    Phase 1 — spin: transient statuses (peer mid-operation) busy-retry up
    to ``spins`` times; the peer commits within a bounded instruction count.
    Phase 2 — yield: stable statuses (or exhausted spins) give up the
    processor with ``sleep(0)`` for ``yields`` attempts.
    Phase 3 — sleep: persistent emptiness/fullness sleeps, doubling from
    ``sleep_init`` up to ``sleep_max`` — never a fixed busy-wait, never
    unbounded latency once work arrives.

    ``reset()`` after successful progress restores phase 1.
    """

    __slots__ = ("spins", "yields", "sleep_init", "sleep_max", "_attempt")

    def __init__(self, spins: int = 32, yields: int = 16,
                 sleep_init: float = 50e-6, sleep_max: float = 2e-3):
        self.spins, self.yields = spins, yields
        self.sleep_init, self.sleep_max = sleep_init, sleep_max
        self._attempt = 0

    def reset(self) -> None:
        self._attempt = 0

    def wait(self, status: int = BUFFER_EMPTY) -> None:
        """Wait appropriately for ``status``; escalates across calls."""
        if status in TRANSIENT and self._attempt < self.spins:
            self._attempt += 1
            return                       # spin: retry immediately
        k = self._attempt - self.spins
        self._attempt += 1
        if k < self.yields:
            time.sleep(0)                # yield the processor
            return
        delay = min(self.sleep_init * (2 ** min(k - self.yields, 20)),
                    self.sleep_max)
        time.sleep(delay)


def send_blocking(t: Transport, payload: Any, *,
                  timeout_s: Optional[float] = None,
                  should_stop: Optional[Callable[[], bool]] = None) -> bool:
    """Retry ``t.send`` with :class:`Backoff` until OK.  Returns False on
    timeout or when ``should_stop()`` turns true (payload not delivered)."""
    b = Backoff()
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    while True:
        status = t.send(payload)
        if status == OK:
            return True
        if should_stop is not None and should_stop():
            return False
        if deadline is not None and time.monotonic() > deadline:
            return False
        b.wait(status)


def recv_blocking(t: Transport, *, timeout_s: Optional[float] = None,
                  should_stop: Optional[Callable[[], bool]] = None
                  ) -> Tuple[int, Optional[Any]]:
    """Retry ``t.try_recv`` until OK; returns the last (status, payload) on
    timeout/stop so callers can distinguish empty from delivered."""
    b = Backoff()
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    while True:
        status, payload = t.try_recv()
        if status == OK:
            return status, payload
        if should_stop is not None and should_stop():
            return status, None
        if deadline is not None and time.monotonic() > deadline:
            return status, None
        b.wait(status)


def drain(t: Transport, max_items: Optional[int] = None) -> List[Any]:
    """Generic drain: repeated try_recv until a non-OK status.  Any
    transport gets this for free; implementations may override."""
    out: List[Any] = []
    while max_items is None or len(out) < max_items:
        status, payload = t.try_recv()
        if status != OK:
            break
        out.append(payload)
    return out


class StateTransport:
    """NBW state cell as a Transport (paper §7 state-message policy).

    ``send`` never blocks and never reports FULL (the NBW Non-blocking
    property).  ``try_recv`` maps NBW statuses onto Table 1: a read
    collision or in-progress write is "producer inserting" (transient —
    spin and retry); an unpublished cell is plain EMPTY (stable).  A
    successful recv returns the *freshest* committed value; re-reads of
    the same value are legal (state semantics, not FIFO).
    """

    __slots__ = ("cell",)

    def __init__(self, cell: nbw.HostNBW):
        self.cell = cell

    def send(self, payload: Any) -> int:
        self.cell.write(payload)
        return OK

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        status, value = self.cell.try_read()
        if status != nbw.OK:
            return BUFFER_EMPTY_BUT_PRODUCER_INSERTING, None
        if value is None:               # nothing published yet
            return BUFFER_EMPTY, None
        return OK, value

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        """At most one item: the freshest committed value.  Non-blocking:
        spins only through transient collisions (bounded by the writer's
        commit, per the NBW Timeliness property); stable EMPTY returns
        immediately like every other Transport."""
        for _ in range(64):
            status, value = self.try_recv()
            if status == OK:
                return [value]
            if status not in TRANSIENT:
                break
        return []


class CodecTransport:
    """Encode/decode payloads over an inner transport (e.g. MCAPI scalar
    packing).  Pure composition: status codes pass through untouched."""

    __slots__ = ("inner", "encode", "decode")

    def __init__(self, inner: Transport, encode: Callable[[Any], Any],
                 decode: Callable[[Any], Any]):
        self.inner, self.encode, self.decode = inner, encode, decode

    def send(self, payload: Any) -> int:
        return self.inner.send(self.encode(payload))

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        status, payload = self.inner.try_recv()
        if status == OK:
            payload = self.decode(payload)
        return status, payload

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return [self.decode(p) for p in self.inner.drain(max_items)]
