"""Unified lock-free Transport protocol — one wire format for every queue.

Every host-side communication primitive in this repo (SPSC/MPSC NBB rings,
the mutex baseline, NBW state cells, MCAPI channels) exposes the same
three operations:

  send(payload) -> status            non-blocking insert/publish
  try_recv()    -> (status, payload) non-blocking read
  drain(max_items) -> [payload, ..]  take everything available *now*
  send_burst(vals) -> (status, n)    packet-mode insert of a block
  drain_burst(max_n) -> [payload,..] packet-mode read of a block

with the paper's Table-1 status codes (``repro.core.nbb``):

  OK                                   operation committed
  BUFFER_FULL                          stable:    yield, retry later
  BUFFER_FULL_BUT_CONSUMER_READING     transient: spin, retry immediately
  BUFFER_EMPTY                         stable:    yield, retry later
  BUFFER_EMPTY_BUT_PRODUCER_INSERTING  transient: spin, retry immediately

The split into *stable* and *transient* failures is the paper's retry
discipline: a transient status means the peer is mid-operation (an odd
counter) and will commit within a bounded number of instructions, so the
caller should busy-retry; a stable status means progress depends on the
peer being scheduled at all, so the caller should yield — and, if the
condition persists, back off exponentially rather than burn the core.
:class:`Backoff` packages that policy; :func:`send_blocking` /
:func:`recv_blocking` are the canonical retry loops built on it.

The burst pair is the paper's *packet mode* (Tables 5-7): per-exchange
overhead dominates when data moves one scalar at a time, so ring
transports reserve a contiguous slot span with ONE counter
announce/commit pair and move the whole block (``HostNBB.send_burst`` /
``drain_burst``); non-ring transports fall back to the generic loops
below, keeping the surface uniform.

STATE (NBW) cells join the protocol through :class:`StateTransport`,
which maps the NBW collision statuses onto Table 1 (a collision *is*
"producer inserting").  Scalar channels wrap any transport in a
:class:`CodecTransport` so the packing happens in the transport stack,
not in per-call ``ChannelType`` dispatch (see DESIGN.md §3).

Non-blocking operation handles (MCAPI ``mcapi_*_i`` / ``mcapi_test`` /
``mcapi_wait`` / ``mcapi_cancel``, paper §2): :func:`send_i` /
:func:`recv_i` return an :class:`OpHandle` immediately instead of
retrying inline.  The handle owns a two-state CAS FSM
(PENDING -> COMPLETED | CANCELLED, ``repro.core.states``); callers
overlap their own work with the in-flight exchange and poll with
``test()``, park with ``wait()``, or abandon with ``cancel()`` — a
concurrent cancel and completion race through one CAS, so exactly one
terminal state wins.  The blocking calls below (:func:`send_blocking`,
:func:`recv_blocking`) are thin wrappers: handle + ``wait`` (DESIGN.md
§5).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Protocol, Tuple, runtime_checkable

from repro.core import faults, nbb, nbw, states
from repro.core import interleave as _il

# Table-1 status codes, re-exported so transport users need one import.
OK = nbb.OK
BUFFER_FULL = nbb.BUFFER_FULL
BUFFER_FULL_BUT_CONSUMER_READING = nbb.BUFFER_FULL_BUT_CONSUMER_READING
BUFFER_EMPTY = nbb.BUFFER_EMPTY
BUFFER_EMPTY_BUT_PRODUCER_INSERTING = nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING

#: Statuses where the peer is mid-operation: retry immediately (spin).
TRANSIENT = frozenset({BUFFER_FULL_BUT_CONSUMER_READING,
                       BUFFER_EMPTY_BUT_PRODUCER_INSERTING})


@runtime_checkable
class Transport(Protocol):
    """Anything that moves payloads with Table-1 status codes."""

    def send(self, payload: Any) -> int: ...

    def try_recv(self) -> Tuple[int, Optional[Any]]: ...

    def drain(self, max_items: Optional[int] = None) -> List[Any]: ...

    def send_i(self, payload: Any) -> "OpHandle": ...

    def recv_i(self) -> "OpHandle": ...

    def send_burst(self, vals) -> Tuple[int, int]: ...

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]: ...


class Backoff:
    """Bounded exponential backoff implementing the Table-1 retry discipline.

    Phase 1 — spin: transient statuses (peer mid-operation) busy-retry up
    to ``spins`` times; the peer commits within a bounded instruction count.
    Phase 2 — yield: stable statuses (or exhausted spins) give up the
    processor with ``sleep(0)`` for ``yields`` attempts.
    Phase 3 — sleep: persistent emptiness/fullness sleeps, doubling from
    ``sleep_init`` up to ``sleep_max`` — never a fixed busy-wait, never
    unbounded latency once work arrives.

    ``reset()`` after successful progress restores phase 1.
    """

    __slots__ = ("spins", "yields", "sleep_init", "sleep_max", "_attempt")

    def __init__(self, spins: int = 32, yields: int = 16,
                 sleep_init: float = 50e-6, sleep_max: float = 2e-3):
        self.spins, self.yields = spins, yields
        self.sleep_init, self.sleep_max = sleep_init, sleep_max
        self._attempt = 0

    def reset(self) -> None:
        self._attempt = 0

    def wait(self, status: int = BUFFER_EMPTY) -> None:
        """Wait appropriately for ``status``; escalates across calls."""
        if _il._active is not None:
            # Under the deterministic scheduler, waiting IS yielding: the
            # scheduler decides who runs next, so spinning or sleeping for
            # wall-clock time would only burn the model checker's budget
            # (and a time.sleep would deadlock the one-runner handshake).
            _il._active.yield_point("backoff.wait", status)
            return
        if status in TRANSIENT and self._attempt < self.spins:
            self._attempt += 1
            return                       # spin: retry immediately
        k = self._attempt - self.spins
        self._attempt += 1
        if k < self.yields:
            time.sleep(0)                # yield the processor
            return
        delay = min(self.sleep_init * (2 ** min(k - self.yields, 20)),
                    self.sleep_max)
        time.sleep(delay)


class OpHandle:
    """A non-blocking operation in flight (MCAPI ``mcapi_request_t``).

    Wraps one retriable attempt (a send or a receive) behind the
    PENDING -> COMPLETED | CANCELLED CAS FSM of ``repro.core.states``:

      * ``test()``   — one poll: run the attempt once, commit on OK
                       (mcapi_test); never blocks.
      * ``wait()``   — poll under the Table-1 :class:`Backoff` discipline
                       until terminal, timeout, or ``should_stop``
                       (mcapi_wait).  A timeout leaves the handle PENDING
                       — the operation can still be polled or cancelled.
      * ``cancel()`` — CAS PENDING -> CANCELLED (mcapi_cancel).  Safe
                       from any thread; returns True iff this caller's
                       proposal won (the op will never commit as
                       COMPLETED).

    Threading contract: ``test``/``wait`` run the underlying queue
    operation, so they must be called from the thread that owns that
    side of the transport (the single producer for a send handle, the
    single consumer for a recv handle).  ``cancel`` only touches the
    FSM and may race from anywhere.  If an attempt's side effect lands
    in the same instant a cancel wins the CAS (the unavoidable window
    between the queue op and the commit CAS), the value is parked in
    ``late_result`` instead of being lost, and the handle still reports
    CANCELLED — exactly one terminal state, no double delivery.
    """

    __slots__ = ("_attempt", "_fsm", "result", "late_result", "last_status",
                 "attempted_ok")

    def __init__(self, attempt: Callable[[], Tuple[int, Any]],
                 name: str = "op"):
        self._attempt = attempt        # () -> (Table-1 status, payload)
        self._fsm = states.StateCell(states.OP_TRANSITIONS,
                                     states.OP_PENDING, name)
        self.result: Any = None        # payload once COMPLETED (None for send)
        self.late_result: Any = None   # side effect that lost the CAS race
        self.last_status = BUFFER_EMPTY  # last non-OK status observed
        self.attempted_ok = False      # the queue op itself committed

    @property
    def state(self) -> str:
        return self._fsm.state

    @property
    def done(self) -> bool:
        return self._fsm.state != states.OP_PENDING

    @property
    def completed(self) -> bool:
        return self._fsm.state == states.OP_COMPLETED

    @property
    def cancelled(self) -> bool:
        return self._fsm.state == states.OP_CANCELLED

    def test(self) -> bool:
        """One non-blocking poll; True iff the operation has completed."""
        s = self._fsm.state
        if s == states.OP_COMPLETED:
            return True
        if s == states.OP_CANCELLED:
            return False
        if _il._active is not None:
            _il._active.yield_point("op.attempt", id(self))
        status, payload = self._attempt()
        if status != OK:
            self.last_status = status
            return False
        self.attempted_ok = True
        if _il._active is not None:
            _il._active.yield_point("op.commit", id(self))
        if self._fsm.cas(states.OP_PENDING, states.OP_COMPLETED):
            self.result = payload
            return True
        self.late_result = payload     # cancel won; don't lose the item
        return False

    def wait(self, timeout_s: Optional[float] = None,
             backoff: Optional[Backoff] = None,
             should_stop: Optional[Callable[[], bool]] = None) -> bool:
        """Poll until terminal; True iff COMPLETED.  False on cancel,
        timeout, or ``should_stop`` (the handle stays PENDING on the
        latter two, so the caller may keep polling or cancel)."""
        b = backoff if backoff is not None else Backoff()
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while True:
            if self.test():
                return True
            if self.cancelled:
                return False
            if should_stop is not None and should_stop():
                return False
            if deadline is not None and time.monotonic() > deadline:
                return False
            b.wait(self.last_status)

    def cancel(self) -> bool:
        """CAS PENDING -> CANCELLED; True iff this caller won."""
        if _il._active is not None:
            _il._active.yield_point("op.cancel", id(self))
        return self._fsm.cas(states.OP_PENDING, states.OP_CANCELLED)


def send_i(t: Transport, payload: Any) -> OpHandle:
    """Non-blocking send (``mcapi_msg_send_i``): returns an OpHandle after
    one eager attempt, so the uncontended case is already COMPLETED."""
    h = OpHandle(lambda: (t.send(payload), None), name="send_i")
    h.test()
    return h


def recv_i(t: Transport) -> OpHandle:
    """Non-blocking receive (``mcapi_msg_recv_i``): the received payload
    lands in ``handle.result``.  One eager attempt before returning."""
    h = OpHandle(t.try_recv, name="recv_i")
    h.test()
    return h


def send_blocking(t: Transport, payload: Any, *,
                  timeout_s: Optional[float] = None,
                  should_stop: Optional[Callable[[], bool]] = None) -> bool:
    """Blocking send = handle + wait (DESIGN.md §5 layering).  Returns
    False on timeout or when ``should_stop()`` turns true (payload not
    delivered)."""
    return send_i(t, payload).wait(timeout_s=timeout_s,
                                   should_stop=should_stop)


def recv_blocking(t: Transport, *, timeout_s: Optional[float] = None,
                  should_stop: Optional[Callable[[], bool]] = None
                  ) -> Tuple[int, Optional[Any]]:
    """Blocking receive = handle + wait; returns the last (status, None)
    on timeout/stop so callers can distinguish empty from delivered."""
    h = recv_i(t)
    if h.wait(timeout_s=timeout_s, should_stop=should_stop):
        return OK, h.result
    return h.last_status, None


def drain(t: Transport, max_items: Optional[int] = None) -> List[Any]:
    """Generic drain: repeated try_recv until a non-OK status.  Any
    transport gets this for free; implementations may override."""
    out: List[Any] = []
    while max_items is None or len(out) < max_items:
        status, payload = t.try_recv()
        if status != OK:
            break
        out.append(payload)
    return out


# ---------------------------------------------------------------------------
# Packet-mode burst exchange (paper Tables 5-7).  Ring transports override
# these with a true span reservation (one counter announce/commit pair and
# two slice copies — ``HostNBB.send_burst``/``drain_burst``); the generic
# forms below give every other transport the same surface by looping the
# scalar ops, so callers can always hand over a block and let the transport
# decide how much of the exchange is amortized.
# ---------------------------------------------------------------------------
def send_burst(t: Transport, vals) -> Tuple[int, int]:
    """Generic burst send: the longest prefix of ``vals`` the transport
    accepts.  Returns ``(status, n_sent)`` — OK iff everything landed,
    else the first non-OK status observed."""
    for i, v in enumerate(vals):
        status = t.send(v)
        if status != OK:
            return status, i
    return OK, len(vals)


def drain_burst(t: Transport, max_n: Optional[int] = None) -> List[Any]:
    """Generic burst drain: alias of :func:`drain` for transports with no
    native span reservation."""
    return drain(t, max_n)


class StateTransport:
    """NBW state cell as a Transport (paper §7 state-message policy).

    ``send`` never blocks and never reports FULL (the NBW Non-blocking
    property).  ``try_recv`` maps NBW statuses onto Table 1: a read
    collision or in-progress write is "producer inserting" (transient —
    spin and retry); an unpublished cell is plain EMPTY (stable).  A
    successful recv returns the *freshest* committed value; re-reads of
    the same value are legal (state semantics, not FIFO).
    """

    __slots__ = ("cell",)

    def __init__(self, cell: nbw.HostNBW):
        self.cell = cell

    def send(self, payload: Any) -> int:
        self.cell.write(payload)
        return OK

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        status, value = self.cell.try_read()
        if status != nbw.OK:
            return BUFFER_EMPTY_BUT_PRODUCER_INSERTING, None
        if value is None:               # nothing published yet
            return BUFFER_EMPTY, None
        return OK, value

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        """At most one item: the freshest committed value.  Non-blocking:
        spins only through transient collisions (bounded by the writer's
        commit, per the NBW Timeliness property); stable EMPTY returns
        immediately like every other Transport."""
        for _ in range(64):
            status, value = self.try_recv()
            if status == OK:
                return [value]
            if status not in TRANSIENT:
                break
        return []

    def send_burst(self, vals) -> Tuple[int, int]:
        """State semantics: every value is published (writes never block);
        only the last one survives as the freshest committed state."""
        return send_burst(self, vals)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        """At most one item — the freshest committed value (see drain)."""
        if max_n is not None and max_n <= 0:
            return []
        return self.drain(max_n)

    def send_i(self, payload: Any) -> OpHandle:
        return send_i(self, payload)

    def recv_i(self) -> OpHandle:
        return recv_i(self)


class PriorityTransport:
    """Strict-priority fan over N inner transports: class 0 is the most
    urgent, and ``try_recv``/``drain`` always serve the lowest-numbered
    nonempty class first, FIFO within a class — the MCAPI "priority
    FIFO" delivery order MESSAGE channels document (the reference
    implementation's ``mcapi_msg_send`` priority argument).

    Composition keeps it lock-free: each class is its own SPSC ring, so
    the single-writer invariant holds per ring and the consumer's
    priority scan is just N non-blocking probes — no ordered shared
    structure, no lock (the same per-class-ring construction the serving
    engine's :class:`repro.serve.overload.PriorityIntake` uses across
    producers).

    ``send`` without a priority lands in ``default_class`` (the least
    urgent, so unprioritized traffic never preempts prioritized);
    ``send_to`` targets an explicit class, clamped into range."""

    __slots__ = ("classes", "default_class")

    def __init__(self, classes: List["Transport"],
                 default_class: Optional[int] = None):
        if not classes:
            raise ValueError("PriorityTransport needs >= 1 class")
        self.classes = list(classes)
        self.default_class = (len(classes) - 1 if default_class is None
                              else default_class)

    def send(self, payload: Any) -> int:
        return self.classes[self.default_class].send(payload)

    def send_to(self, payload: Any, priority: int) -> int:
        p = max(0, min(len(self.classes) - 1, int(priority)))
        return self.classes[p].send(payload)

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        busy = False
        for p, t in enumerate(self.classes):
            if _il._active is not None:
                _il._active.yield_point("transport.priority.scan",
                                        (id(self), p))
            status, payload = t.try_recv()
            if status == OK:
                return OK, payload
            if status in TRANSIENT:
                busy = True
        return ((BUFFER_EMPTY_BUT_PRODUCER_INSERTING if busy
                 else BUFFER_EMPTY), None)

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return drain(self, max_items)

    def send_burst(self, vals) -> Tuple[int, int]:
        return self.classes[self.default_class].send_burst(vals)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        """Priority-ordered burst: one span reservation per class ring,
        most urgent first."""
        out: List[Any] = []
        for t in self.classes:
            take = None if max_n is None else max_n - len(out)
            if take is not None and take <= 0:
                break
            out.extend(t.drain_burst(take))
        return out

    def send_i(self, payload: Any) -> OpHandle:
        return send_i(self, payload)

    def recv_i(self) -> OpHandle:
        return recv_i(self)


class FaultyTransport:
    """Inject transport-site faults from a :class:`repro.core.faults.FaultPlan`
    in front of any inner transport.  Refusals surface as the Table-1
    statuses the caller already handles (FULL on send, EMPTY on recv) —
    a fault at the transport layer is indistinguishable from pressure,
    which is the point: every retry loop in the system is exercised by
    the same plan that exercises the crash paths.

    The ``stall`` action models a producer dying mid-span-reservation:
    when the inner transport is a counter ring the announced-but-
    uncommitted span is actually left in the ring
    (:func:`repro.core.faults.stall_mid_burst`) before a non-retryable
    :class:`~repro.core.faults.InjectedFault` marks the producer dead.
    Recovery is the owner's job (``recover_ring``), mirroring the lease
    contract.

    Probes use the base site names (``transport.send`` etc.) so plans
    address a site class, not an instance; ``name`` only labels the
    wrapper for debugging."""

    __slots__ = ("inner", "plan", "name")

    def __init__(self, inner: Transport, plan: "faults.FaultPlan",
                 name: str = ""):
        self.inner, self.plan, self.name = inner, plan, name

    def _stall(self, vals) -> "Tuple[int, int]":
        ring = self.inner
        if hasattr(ring, "_uc"):
            faults.stall_mid_burst(ring, list(vals))
        raise faults.InjectedFault("transport.stall", self.plan.n_fired,
                                   retryable=False)

    def send(self, payload: Any) -> int:
        act = self.plan.fire("transport.send")
        if act is None:
            return self.inner.send(payload)
        if act == faults.ACT_RAISE:
            raise faults.InjectedFault("transport.send", self.plan.n_fired)
        return BUFFER_FULL

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        act = self.plan.fire("transport.recv")
        if act is None:
            return self.inner.try_recv()
        if act == faults.ACT_RAISE:
            raise faults.InjectedFault("transport.recv", self.plan.n_fired)
        return BUFFER_EMPTY, None

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        act = self.plan.fire("transport.recv")
        if act is None:
            return self.inner.drain(max_items)
        if act == faults.ACT_RAISE:
            raise faults.InjectedFault("transport.recv", self.plan.n_fired)
        return []

    def send_burst(self, vals) -> Tuple[int, int]:
        act = self.plan.fire("transport.send_burst")
        if act == faults.ACT_STALL:
            return self._stall(vals)
        if act is not None:
            return BUFFER_FULL, 0
        if self.plan.fire("transport.stall") is not None:
            return self._stall(vals)
        return self.inner.send_burst(vals)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        act = self.plan.fire("transport.recv")
        if act is None:
            return self.inner.drain_burst(max_n)
        if act == faults.ACT_RAISE:
            raise faults.InjectedFault("transport.recv", self.plan.n_fired)
        return []

    def send_i(self, payload: Any) -> OpHandle:
        return send_i(self, payload)

    def recv_i(self) -> OpHandle:
        return recv_i(self)


class CodecTransport:
    """Encode/decode payloads over an inner transport (e.g. MCAPI scalar
    packing).  Pure composition: status codes pass through untouched."""

    __slots__ = ("inner", "encode", "decode")

    def __init__(self, inner: Transport, encode: Callable[[Any], Any],
                 decode: Callable[[Any], Any]):
        self.inner, self.encode, self.decode = inner, encode, decode

    def send(self, payload: Any) -> int:
        return self.inner.send(self.encode(payload))

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        status, payload = self.inner.try_recv()
        if status == OK:
            payload = self.decode(payload)
        return status, payload

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return [self.decode(p) for p in self.inner.drain(max_items)]

    def send_burst(self, vals) -> Tuple[int, int]:
        """Encode the block once, hand it to the inner ring's native span
        reservation — the packing rides the packet, not per-item calls.
        The whole block is encoded before the ring reports how much fits,
        so a caller retrying a rejected suffix re-encodes it; fine for
        the fire-and-forget streaming path, something to know for a
        tight retry loop under sustained backpressure."""
        return self.inner.send_burst([self.encode(v) for v in vals])

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        return [self.decode(p) for p in self.inner.drain_burst(max_n)]

    def send_i(self, payload: Any) -> OpHandle:
        return send_i(self, payload)

    def recv_i(self) -> OpHandle:
        return recv_i(self)
