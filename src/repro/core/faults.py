"""Deterministic fault injection: seeded, schedule-addressable plans.

The paper's position is that removing locks is only acceptable once the
system's properties are *validated* — and partial failure is the
property lock-free designs are hardest on (a died producer cannot be
"unlocked" by anyone; the protocol itself must make its half-finished
operation harmless).  This module provokes those failures on purpose:

  * A :class:`FaultPlan` is a list of :class:`FaultRule`\\ s, each
    addressing an injection SITE by name (exact or fnmatch pattern) and
    a schedule ("fail the ``nth`` matching probe, for ``times``
    consecutive probes").  Sites are threaded through the transport
    layer (:class:`repro.core.transport.FaultyTransport`), the page pool
    (``serve/kv_cache.py``) and the serve engine (``serve/engine.py``);
    each calls ``plan.fire(site)`` at its probe point and acts on the
    returned action — or does nothing when no plan is armed, so the
    zero-fault fast path costs one ``is None`` check.
  * Plans are pure host-side counters: given the same single-threaded
    probe sequence, the same plan fires at the same operations — which
    is what lets the fault sweep assert byte-identical survivor tokens
    against a no-fault run (benchmarks/bench_faults.py).
  * ``stall_mid_burst`` / ``recover_ring`` model the one failure a
    refusal cannot: a producer dying BETWEEN the announce and the commit
    of an NBB span reservation.  The ring is left with an odd update
    counter — consumers correctly see only the committed prefix (the
    Table-1 transient status, never a torn span) — and recovery is a
    single producer-side counter rollback, legal exactly when the
    producer is known dead (the engine's lease contract, DESIGN.md §13).

Default action per site (a rule with ``action=None`` uses it):

  refuse   — the probe's caller returns its Table-1/POOL_FULL refusal
             status; the operation simply did not happen (every refusal
             site is a path the system already handles under pressure).
  raise    — the probe raises :class:`InjectedFault` (retryable: the
             engine's tick watchdog may retry the tick).
  stall    — producer dies mid-span-reservation (transports only).
  poison   — a page write is declared corrupted; the engine quarantines
             the implicated pages and fails the slot.
  timeout  — a device sync that never returns; raised as a
             non-retryable :class:`InjectedFault` (the device state is
             past the point a retry could reconcile).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import random
from contextlib import contextmanager
from typing import List, Optional, Sequence

ACT_REFUSE = "refuse"
ACT_RAISE = "raise"
ACT_STALL = "stall"
ACT_POISON = "poison"
ACT_TIMEOUT = "timeout"

#: Site catalog: every probe point in the system and its default action.
SITES = {
    "transport.send": ACT_REFUSE,        # response-ring scalar insert
    "transport.recv": ACT_REFUSE,        # intake pop / client drain
    "transport.send_burst": ACT_REFUSE,  # stream-ring span insert
    "transport.stall": ACT_STALL,        # producer dies mid-reservation
    "pool.claim": ACT_REFUSE,            # admission page claim
    "pool.extend": ACT_REFUSE,           # chunked reservation growth
    "pool.cow": ACT_REFUSE,              # copy-on-write privatization
    "pool.swap_out": ACT_RAISE,          # preemption gather (pre-mutation)
    "pool.swap_in": ACT_REFUSE,          # resume re-claim
    "pool.page_write": ACT_POISON,       # KV write declared corrupted
    "engine.dispatch": ACT_RAISE,        # jitted call refuses to launch
    "engine.sync": ACT_TIMEOUT,          # device->host fetch "hangs"
    "snapshot.write": ACT_REFUSE,        # process dies mid-snapshot (torn file)
    "snapshot.restore": ACT_REFUSE,      # restore aborts before mutation
    "journal.append": ACT_REFUSE,        # WAL record lost at BIND
}


class InjectedFault(RuntimeError):
    """Raised at a ``raise``/``stall``/``timeout`` site.  ``retryable``
    tells the tick watchdog whether re-running the tick from the top can
    reconcile (pre-dispatch host bookkeeping is idempotent) or the
    device already advanced past what the host harvested (it cannot)."""

    def __init__(self, site: str, seq: int = 0, retryable: bool = True):
        super().__init__(f"injected fault at {site} (fire #{seq})")
        self.site = site
        self.seq = seq
        self.retryable = retryable


@dataclasses.dataclass
class FaultRule:
    """Fire at probes ``nth .. nth+times-1`` of sites matching ``site``
    (exact name or fnmatch pattern, e.g. ``"pool.*"``).  ``action=None``
    uses the site's catalog default.  ``times`` is finite by default so
    every plan eventually goes quiet — the sweep's convergence
    guarantee."""

    site: str
    nth: int = 1
    times: int = 1
    action: Optional[str] = None


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    ``probe(site)`` advances every matching rule's probe counter and
    returns the first rule inside its firing window (appending to the
    ``fired`` log), or None.  ``fire(site)`` resolves the rule to its
    action string.  ``pause()`` suspends firing (a context manager) so
    recovery code — the watchdog failing slots, the lease reaper — can
    use the same transports without recursing into fresh faults.

    Probe counters are plain ints under the GIL; the sweep harness
    drives engine and client from one thread, where the probe sequence
    (and therefore the fire schedule) is fully deterministic.
    """

    def __init__(self, rules: Sequence[FaultRule], name: str = ""):
        self.rules = list(rules)
        self.name = name
        self._counts = [0] * len(self.rules)
        self.fired: List[str] = []      # site name per fire, in order
        self._paused = 0

    def __repr__(self) -> str:
        return (f"FaultPlan({self.name or 'anon'}, "
                f"{len(self.rules)} rules, {self.n_fired} fired)")

    @property
    def n_fired(self) -> int:
        return len(self.fired)

    @contextmanager
    def pause(self):
        """Suspend firing while recovery code runs (re-entrant)."""
        self._paused += 1
        try:
            yield self
        finally:
            self._paused -= 1

    def probe(self, site: str) -> Optional[FaultRule]:
        if self._paused:
            return None
        hit = None
        for i, r in enumerate(self.rules):
            if r.site == site or fnmatch.fnmatchcase(site, r.site):
                self._counts[i] += 1
                if hit is None and r.nth <= self._counts[i] < r.nth + r.times:
                    hit = r
        if hit is not None:
            self.fired.append(site)
        return hit

    def fire(self, site: str) -> Optional[str]:
        """Probe; the action string when a rule fires, else None."""
        rule = self.probe(site)
        if rule is None:
            return None
        return rule.action or SITES.get(site, ACT_RAISE)

    @classmethod
    def random(cls, seed: int, n_rules: int = 3,
               sites: Optional[Sequence[str]] = None, max_nth: int = 6,
               max_times: int = 2, name: str = "") -> "FaultPlan":
        """A seeded random plan over ``sites`` (default: the catalog).
        Same seed, same rules — the schedule is reproducible."""
        rng = random.Random(seed)
        pool = list(sites) if sites is not None else list(SITES)
        rules = [FaultRule(site=rng.choice(pool),
                           nth=rng.randint(1, max_nth),
                           times=rng.randint(1, max_times))
                 for _ in range(n_rules)]
        return cls(rules, name=name or f"random-{seed}")

    @classmethod
    def sweep(cls, n_plans: int, seed: int = 0,
              sites: Optional[Sequence[str]] = None,
              extra_rules: int = 1) -> List["FaultPlan"]:
        """The fault-matrix sweep: plan ``i`` pins one early-firing rule
        to site ``i % len(sites)`` (round-robin, so every site class is
        targeted ~``n_plans/len(sites)`` times across the sweep) plus
        ``extra_rules`` random riders.  Pinned rules fire on the 1st or
        2nd matching probe — rare sites (swap, CoW) are probed only a
        handful of times per run, and a deep ``nth`` would silently turn
        their plans into no-ops."""
        pool = list(sites) if sites is not None else list(SITES)
        plans = []
        for i in range(n_plans):
            rng = random.Random(seed * 1000003 + i)
            pinned = FaultRule(site=pool[i % len(pool)],
                               nth=rng.randint(1, 2),
                               times=rng.randint(1, 2))
            riders = [FaultRule(site=rng.choice(pool),
                                nth=rng.randint(1, 6), times=1)
                      for _ in range(extra_rules)]
            plans.append(cls([pinned] + riders, name=f"sweep-{seed}-{i}"))
        return plans


# ---------------------------------------------------------------------------
# Producer-death helpers for NBB rings (HostNBB counter protocol).
# ---------------------------------------------------------------------------
def stall_mid_burst(ring, vals) -> int:
    """Simulate a producer dying mid-``send_burst``: announce the span
    (odd update counter), write some slots, never commit.  Consumers
    observe only the committed prefix — ``drain_burst`` computes
    availability from ``uc // 2``, which excludes the announced span,
    and ``read_item`` on the boundary reports the Table-1 transient
    status — so no torn or reordered span is ever visible.  Returns the
    span size that died (0 when the ring was full: the producer died
    before announcing, leaving the ring untouched)."""
    uc = ring._uc
    ac = ring._ac
    space = ring._n - ((uc // 2) - (ac // 2))
    m = min(space, len(vals))
    if m <= 0:
        return 0
    ring._uc = uc + 1                   # announce ... and die: no commit
    start = (uc // 2) % ring._n
    for j in range(m):
        ring._slots[(start + j) % ring._n] = vals[j]
    return m


def recover_ring(ring) -> bool:
    """Roll back a dead producer's announced-but-uncommitted span (the
    odd update counter): one counter store returns the ring to its last
    committed state, ready for a new producer.

    This writes the PRODUCER-owned counter, so it is legal only when the
    producer is known dead — the engine invokes it from the lease reaper
    (a client past its lease is presumed dead, DESIGN.md §13) and from
    the tick watchdog on its own rings (the engine thread IS the
    producer there).  True iff a span was rolled back."""
    uc = getattr(ring, "_uc", None)
    if uc is None or not uc & 1:
        return False
    ring._uc = uc - 1
    return True
