"""Non-Blocking Write (NBW) protocol — lock-free *state* messaging.

Kopetz & Reisinger's NBW protocol (RTSS 1993) as summarized in Section 3 of
the paper: a single atomic version counter guards an array of buffers.

  writer:  c += 1 ; write buffer[(c//2) mod K] ; c += 1
  reader:  c0 = c ; (retry if odd) ; read buffer ; c1 = c ;
           success iff c1 == c0, else retry (bounded).

State messages are *indeterminate order* — the reader always wants the most
recent value.  The writer is never blocked by readers (the paper's
Non-blocking property); readers detect collisions optimistically (Safety)
and their retry count is bounded by buffer depth (Timeliness).

Framework uses:
  * publishing parameter snapshots from the training loop to the async
    checkpointer without stalling the step (``repro.train.checkpoint``),
  * publishing fresh weights to a serving engine (weight hot-swap),
  * scalar telemetry (step counter, loss) between host actors.
"""
from __future__ import annotations

import copy
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax.numpy as jnp

# Status codes for the explicit (non-retrying) reader.
OK = 0
READ_COLLISION = 1   # writer overwrote the slot mid-read; try again
WRITE_IN_PROGRESS = 2


class HostNBW:
    """Host-side NBW slot: one writer thread, any number of reader threads.

    The version counter is a plain int (atomic store/load under CPython).
    ``depth`` > 1 makes collisions rarer, exactly as the paper notes: "the
    more array buffers there are, the less likely a collision".
    """

    __slots__ = ("_depth", "_bufs", "_version", "_copy")

    def __init__(self, depth: int = 2, deepcopy: bool = False):
        if depth < 1:
            raise ValueError("NBW depth must be >= 1")
        self._depth = depth
        self._bufs: list = [None] * depth
        self._version = 0
        self._copy: Callable[[Any], Any] = (
            copy.deepcopy if deepcopy else (lambda x: x))

    @property
    def version(self) -> int:
        return self._version // 2

    def write(self, value: Any) -> None:
        """Publish a new value.  Never blocks, regardless of readers."""
        v = self._version
        self._version = v + 1                       # odd: write in progress
        self._bufs[((v // 2) + 1) % self._depth] = self._copy(value)
        self._version = v + 2                       # commit new version

    def try_read(self) -> Tuple[int, Optional[Any]]:
        """One optimistic read attempt (explicit status, no spinning)."""
        v0 = self._version
        if v0 & 1:
            return WRITE_IN_PROGRESS, None
        value = self._bufs[(v0 // 2) % self._depth]
        if self._version != v0:
            return READ_COLLISION, None
        return OK, value

    def read(self, max_retries: int = 1 << 16) -> Any:
        """Spin (lock-free, bounded) until an uncorrupted read succeeds."""
        for _ in range(max_retries):
            status, value = self.try_read()
            if status == OK:
                return value
        raise TimeoutError("NBW read retries exhausted (writer storm)")


# ---------------------------------------------------------------------------
# Functional JAX variant — versioned state cell as a pytree.
# ---------------------------------------------------------------------------
class NBWState(NamedTuple):
    version: jnp.ndarray  # i32, even = stable
    bufs: jnp.ndarray     # [depth, *item_shape]


def init(depth: int, item) -> NBWState:
    return NBWState(
        version=jnp.zeros((), jnp.int32),
        bufs=jnp.zeros((depth,) + tuple(item.shape), item.dtype),
    )


def write(state: NBWState, value: jnp.ndarray) -> NBWState:
    depth = state.bufs.shape[0]
    v = state.version
    idx = ((v // 2) + 1) % depth
    return NBWState(v + 2, state.bufs.at[idx].set(value.astype(state.bufs.dtype)))


def read(state: NBWState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (value, version). Functional form is collision-free by
    construction; collision semantics are exercised via the host variant."""
    depth = state.bufs.shape[0]
    idx = (state.version // 2) % depth
    return state.bufs[idx], state.version // 2
