"""Deterministic interleaving control: schedule-driven model checking.

The paper's claim is that *packaging* lock-free algorithms removes
concurrency defects from application code — which makes the package
itself the single point where a defect would be catastrophic, and the
repo's real-thread stress tests cannot reproduce a failure they
provoke, let alone enumerate the interleavings they missed.  This
module makes every interleaving of the lock-free core a first-class,
replayable object:

  * A :class:`VirtualScheduler` runs N logical tasks — ordinary Python
    callables exercising the REAL primitives (``HostNBB``,
    ``MpscQueue``, ``StateCell``, ``RefCountArray``, ``HostBitset``,
    ``OpHandle`` ...) — under cooperative control.  Each task is a real
    thread, but exactly ONE runs at any moment: at every instrumented
    shared-memory access the running task parks and the scheduler picks
    who advances next.  Between yield points execution is atomic, which
    matches CPython's bytecode-atomicity memory model (the model the
    host primitives are written against).
  * Yield points are threaded through the primitives via the
    module-level hook ``_active`` — the same style and the same
    zero-overhead-unarmed guarantee as ``core/faults.py`` sites: the
    unarmed fast path is one ``is None`` check per site, the hook fires
    zero times, and no scheduler machinery is ever constructed.
  * :func:`explore` is a bounded-DFS stateless model checker: it
    re-executes a scenario along every schedule prefix, branching at
    each step over the enabled tasks, with state-fingerprint pruning
    (two executions reaching the same (structure state, task program
    counters) have identical futures, so one subtree suffices).
  * :func:`fuzz` is a seeded random-schedule explorer for scenarios too
    large to enumerate; a failure is automatically shrunk by
    :func:`minimize` (truncation + ddmin over the choice list) and is
    reproducible from ``(seed, run)`` alone — the printed repro line is
    the whole bug report.
  * Schedules serialize to JSON (:func:`save_schedule` /
    :func:`load_schedule`) so minimized counterexamples live in
    ``tests/schedules/`` as a tier-1 replay corpus.

The linearizability checker, sequential specs and the torn-read
detector that consume the traces produced here live in
``repro.checker`` (this module stays dependency-free so every core
primitive may import it).
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# The module-level hook.  Unarmed (`None`) the instrumented sites cost one
# attribute load + `is None` check and nothing else; armed, it is the
# VirtualScheduler currently running and every site parks the calling task.
# ---------------------------------------------------------------------------
_active: Optional["VirtualScheduler"] = None

#: Total yield points taken by armed schedulers (diagnostics; bench_check
#: asserts this stays put across an unarmed hot-path run: zero added ops).
ARMED_HITS = 0


def yield_point(site: str, info: Any = None) -> None:
    """Cold-path convenience hook (hot paths inline the ``_active`` check)."""
    a = _active
    if a is not None:
        a.yield_point(site, info)


class SchedulerAbort(BaseException):
    """Unwinds a task when the scheduler aborts an execution (max_steps,
    or teardown).  BaseException so scenario code cannot swallow it."""


class LivelockError(RuntimeError):
    """An execution exceeded max_steps — under a fair bounded scenario
    this means some task spins without progress."""


class ReplayDivergence(RuntimeError):
    """A replayed schedule chose a task that is not enabled — the
    scenario changed shape since the schedule was recorded."""


# ---------------------------------------------------------------------------
# Tasks and the scheduler.
# ---------------------------------------------------------------------------
class _Task:
    __slots__ = ("tid", "name", "fn", "go", "parked", "thread",
                 "finished", "error", "grants", "site", "info")

    def __init__(self, tid: int, name: str, fn: Callable[[], None]):
        self.tid = tid
        self.name = name
        self.fn = fn
        self.go = threading.Event()
        self.parked = threading.Event()
        self.thread: Optional[threading.Thread] = None
        self.finished = False
        self.error: Optional[BaseException] = None
        self.grants = 0           # times scheduled (the task's "pc" proxy)
        self.site: Optional[str] = None   # site parked at (None = at gate)
        self.info: Any = None


@dataclasses.dataclass
class World:
    """One fresh instance of a scenario: tasks plus optional observers.

    ``tasks``       — list of (name, zero-arg callable) run under control.
    ``fingerprint`` — () -> hashable snapshot of ALL shared state the
                      tasks touch (ring counters+slots, refcounts, FSM
                      state, recorded history...).  Enables DFS pruning;
                      omit it and exploration is purely schedule-tree.
                      Caveat: task-local state not reflected here makes
                      pruning unsound — scenarios route results through
                      a recorded history for exactly this reason.
    ``check``       — () -> None post-run invariant (runs disarmed, in
                      the main thread); raise AssertionError to fail.
    ``history``     — opaque payload for checkers (repro.checker reads
                      recorded op histories through it).
    ``trace``       — filled in by the scheduler before ``check`` runs:
                      the (tid, site, info) yield trace of the execution,
                      so checks can run trace detectors (torn reads).
    """

    tasks: List[Tuple[str, Callable[[], None]]]
    fingerprint: Optional[Callable[[], Any]] = None
    check: Optional[Callable[[], None]] = None
    history: Any = None
    trace: Optional[List[Tuple[int, str, Any]]] = None


@dataclasses.dataclass
class RunResult:
    schedule: Tuple[int, ...]           # task chosen at each step
    enabled: List[Tuple[int, ...]]      # enabled task ids at each step
    fingerprints: List[Any]             # state fp BEFORE each step (or None)
    trace: List[Tuple[int, str, Any]]   # (tid, site, info) per yield point
    error: Optional[BaseException]
    livelocked: bool
    task_names: Tuple[str, ...]

    @property
    def failed(self) -> bool:
        return self.error is not None or self.livelocked


class VirtualScheduler:
    """Runs one World's tasks under cooperative, deterministic control.

    Exactly one task thread runs between two scheduler decisions; the
    handshake is a pair of Events per task (``go`` grants, ``parked``
    returns control at the next yield point or at task completion).
    Determinism therefore needs no cooperation from the GIL: the trace
    is a pure function of the chooser's decisions.
    """

    def __init__(self, world: World, step_timeout_s: float = 30.0):
        self.world = world
        self.tasks = [_Task(i, name, fn)
                      for i, (name, fn) in enumerate(world.tasks)]
        self._by_ident: Dict[int, _Task] = {}
        self._aborting = False
        self._step_timeout_s = step_timeout_s
        self.trace: List[Tuple[int, str, Any]] = []

    # -- called from task threads (via the module hook) ---------------------
    def yield_point(self, site: str, info: Any = None) -> None:
        t = self._by_ident.get(threading.get_ident())
        if t is None:
            return                      # not a controlled task: no-op
        global ARMED_HITS
        ARMED_HITS += 1
        if self._aborting:
            raise SchedulerAbort()
        t.site, t.info = site, info
        self.trace.append((t.tid, site, info))
        t.parked.set()                  # hand control back ...
        t.go.wait()                     # ... and wait to be rescheduled
        t.go.clear()
        if self._aborting:
            raise SchedulerAbort()

    def _task_body(self, t: _Task) -> None:
        t.go.wait()                     # initial gate: wait for first grant
        t.go.clear()
        try:
            if not self._aborting:
                t.fn()
        except SchedulerAbort:
            pass
        except BaseException as e:      # noqa: BLE001 — surfaced as result
            t.error = e
        finally:
            t.finished = True
            t.parked.set()

    # -- main-thread driver --------------------------------------------------
    def run(self, chooser: Callable[[int, Tuple[int, ...], List], int],
            max_steps: int = 2000) -> RunResult:
        global _active
        if _active is not None:
            raise RuntimeError("a VirtualScheduler is already armed")
        schedule: List[int] = []
        enabled_log: List[Tuple[int, ...]] = []
        fps: List[Any] = []
        error: Optional[BaseException] = None
        livelocked = False
        _active = self
        try:
            for t in self.tasks:
                t.thread = threading.Thread(
                    target=self._task_body, args=(t,),
                    name=f"vsched-{t.name}", daemon=True)
                t.thread.start()
                # ident is set by start(); the task blocks at its gate
                # until first granted, so registering here is race-free.
                self._by_ident[t.thread.ident] = t

            step = 0
            while True:
                live = [t for t in self.tasks if not t.finished]
                if not live:
                    break
                if any(t.error for t in self.tasks):
                    break
                if step >= max_steps:
                    livelocked = True
                    break
                enabled = tuple(t.tid for t in live)
                fps.append(self._fingerprint())
                choice = chooser(step, enabled, self.trace)
                if choice not in enabled:
                    raise ReplayDivergence(
                        f"step {step}: chose task {choice}, "
                        f"enabled={enabled}")
                schedule.append(choice)
                enabled_log.append(enabled)
                self._grant(self.tasks[choice])
                step += 1
            error = next((t.error for t in self.tasks if t.error), None)
        finally:
            self._teardown()
            _active = None
        self.world.trace = list(self.trace)
        if error is None and not livelocked and self.world.check is not None:
            try:
                self.world.check()
            except BaseException as e:  # noqa: BLE001 — surfaced as result
                error = e
        return RunResult(schedule=tuple(schedule), enabled=enabled_log,
                         fingerprints=fps, trace=self.trace, error=error,
                         livelocked=livelocked,
                         task_names=tuple(t.name for t in self.tasks))

    def _grant(self, t: _Task) -> None:
        t.parked.clear()
        t.grants += 1
        t.go.set()
        if not t.parked.wait(self._step_timeout_s):
            self._aborting = True
            raise RuntimeError(
                f"task {t.name!r} did not yield within "
                f"{self._step_timeout_s}s — blocking call inside a "
                f"controlled task?")

    def _fingerprint(self) -> Any:
        if self.world.fingerprint is None:
            return None
        pcs = tuple((t.tid, t.grants, t.site, t.finished)
                    for t in self.tasks)
        return (pcs, self.world.fingerprint())

    def _teardown(self) -> None:
        """Drive every unfinished task to completion via SchedulerAbort."""
        self._aborting = True
        for t in self.tasks:
            if t.thread is None:
                continue
            while not t.finished:
                t.parked.clear()
                t.go.set()
                if not t.parked.wait(self._step_timeout_s):
                    break               # leave the daemon thread behind
            t.thread.join(timeout=1.0)


# ---------------------------------------------------------------------------
# Choosers.
# ---------------------------------------------------------------------------
class ReplayChooser:
    """Replay a recorded schedule, then continue first-enabled.

    ``strict=False`` (the minimizer's mode) skips recorded choices that
    are no longer enabled instead of raising, so deleting steps from a
    schedule still yields a meaningful run."""

    def __init__(self, schedule: Sequence[int], strict: bool = True):
        self.schedule = list(schedule)
        self.strict = strict
        self._i = 0

    def __call__(self, step: int, enabled: Tuple[int, ...], trace) -> int:
        while self._i < len(self.schedule):
            c = self.schedule[self._i]
            self._i += 1
            if c in enabled:
                return c
            if self.strict:
                raise ReplayDivergence(
                    f"recorded task {c} not enabled at step {step} "
                    f"(enabled={enabled})")
        return enabled[0]


class RandomChooser:
    """Seeded uniform choice over enabled tasks (the fuzz schedule)."""

    def __init__(self, rng: random.Random):
        self.rng = rng

    def __call__(self, step: int, enabled: Tuple[int, ...], trace) -> int:
        return self.rng.choice(enabled)


def run_schedule(make_world: Callable[[], World],
                 schedule: Sequence[int] = (),
                 max_steps: int = 2000, strict: bool = True,
                 ) -> RunResult:
    """One execution: forced ``schedule`` prefix, then first-enabled."""
    world = make_world()
    sched = VirtualScheduler(world)
    return sched.run(ReplayChooser(schedule, strict=strict),
                     max_steps=max_steps)


# ---------------------------------------------------------------------------
# Bounded-DFS exhaustive exploration with state-fingerprint pruning.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Counterexample:
    schedule: Tuple[int, ...]
    error: str
    error_type: str
    task_names: Tuple[str, ...]
    trace_sites: Tuple[str, ...]
    seed: Optional[int] = None          # set by fuzz(): replay from seed
    run: Optional[int] = None

    def repro(self, scenario: str = "<scenario>") -> str:
        """The printed one-line reproduction recipe."""
        if self.seed is not None:
            return (f"replay: interleave.replay_seed({scenario!r}, "
                    f"seed={self.seed}, run={self.run})  "
                    f"# minimized schedule: {list(self.schedule)}")
        return (f"replay: interleave.run_schedule({scenario!r}, "
                f"schedule={list(self.schedule)})")


@dataclasses.dataclass
class ExploreResult:
    executions: int
    distinct_states: int
    exhausted: bool                     # full tree covered within budget
    counterexample: Optional[Counterexample]
    max_trace_len: int = 0

    @property
    def ok(self) -> bool:
        return self.counterexample is None


def _as_counterexample(res: RunResult) -> Counterexample:
    err = res.error if res.error is not None else LivelockError(
        "execution exceeded max_steps")
    return Counterexample(
        schedule=res.schedule, error=repr(err),
        error_type=type(err).__name__, task_names=res.task_names,
        trace_sites=tuple(s for _, s, _ in res.trace))


def explore(make_world: Callable[[], World], *,
            max_executions: int = 20000, max_steps: int = 2000,
            prune: bool = True) -> ExploreResult:
    """Exhaustive bounded DFS over all interleavings of a scenario.

    Re-executes from scratch per schedule prefix (stateless model
    checking — thread state cannot be forked), branching at every step
    over every enabled task.  With ``prune`` and a World fingerprint,
    a state already branched from is never branched again: two runs
    reaching identical (task pcs, shared state) have identical futures.
    ``exhausted`` is True iff the (pruned) tree was fully covered.
    """
    stack: List[Tuple[int, ...]] = [()]
    branched: set = set()
    distinct: set = set()
    executions = 0
    max_trace = 0
    while stack:
        if executions >= max_executions:
            return ExploreResult(executions, len(distinct), False, None,
                                 max_trace)
        prefix = stack.pop()
        res = run_schedule(make_world, prefix, max_steps=max_steps)
        executions += 1
        max_trace = max(max_trace, len(res.schedule))
        if res.failed:
            return ExploreResult(executions, len(distinct), False,
                                 _as_counterexample(res), max_trace)
        # Branch over the suffix beyond the forced prefix (reversed so
        # the DFS pops low task ids first — deterministic order).
        for i in range(len(res.schedule) - 1, len(prefix) - 1, -1):
            alts = [a for a in res.enabled[i] if a != res.schedule[i]]
            if not alts:
                continue
            fp = res.fingerprints[i]
            if prune and fp is not None:
                if fp in branched:
                    continue
                branched.add(fp)
            for a in alts:
                stack.append(res.schedule[:i] + (a,))
        for fp in res.fingerprints:
            if fp is not None:
                distinct.add(fp)
    return ExploreResult(executions, len(distinct), True, None, max_trace)


# ---------------------------------------------------------------------------
# Seeded random-schedule fuzzing + automatic minimization.
# ---------------------------------------------------------------------------
def _run_seed(make_world: Callable[[], World], seed: int, run: int,
              max_steps: int) -> RunResult:
    rng = random.Random(seed * 1000003 + run)
    world = make_world()
    return VirtualScheduler(world).run(RandomChooser(rng),
                                       max_steps=max_steps)


@dataclasses.dataclass
class FuzzResult:
    runs: int
    counterexample: Optional[Counterexample]
    seed: int

    @property
    def ok(self) -> bool:
        return self.counterexample is None


def fuzz(make_world: Callable[[], World], *, seed: int = 0,
         runs: int = 200, max_steps: int = 2000,
         shrink: bool = True) -> FuzzResult:
    """Random schedules from a seed; first failure is minimized and is
    reproducible from ``(seed, run)`` alone (:func:`replay_seed`)."""
    for k in range(runs):
        res = _run_seed(make_world, seed, k, max_steps)
        if res.failed:
            schedule = res.schedule
            if shrink:
                schedule = minimize(make_world, res, max_steps=max_steps)
            cx = _as_counterexample(
                dataclasses.replace(res, schedule=tuple(schedule)))
            cx.seed, cx.run = seed, k
            return FuzzResult(runs=k + 1, counterexample=cx, seed=seed)
    return FuzzResult(runs=runs, counterexample=None, seed=seed)


def replay_seed(make_world: Callable[[], World], seed: int, run: int,
                max_steps: int = 2000) -> RunResult:
    """Re-run exactly the fuzz execution ``(seed, run)``."""
    return _run_seed(make_world, seed, run, max_steps)


def _same_failure(res: RunResult, ref: RunResult) -> bool:
    if not res.failed:
        return False
    if res.livelocked and ref.livelocked:
        return True
    if res.error is None or ref.error is None:
        return False
    return type(res.error) is type(ref.error)


def minimize(make_world: Callable[[], World], failing: RunResult,
             max_steps: int = 2000) -> Tuple[int, ...]:
    """Delta-debug a failing schedule: truncate the suffix (the default
    first-enabled continuation is deterministic), then ddmin chunk
    deletion, then pointwise deletion.  Replay is tolerant (a deleted
    step's choice may no longer be enabled), so every candidate is a
    meaningful run.  Returns the shortest schedule still reproducing
    the same failure type."""
    def fails(candidate: Sequence[int]) -> bool:
        res = run_schedule(make_world, candidate, max_steps=max_steps,
                           strict=False)
        return _same_failure(res, failing)

    best = list(failing.schedule)
    # Phase 1: binary-search the shortest failing prefix.
    lo, hi = 0, len(best)
    while lo < hi:
        mid = (lo + hi) // 2
        if fails(best[:mid]):
            hi = mid
        else:
            lo = mid + 1
    best = best[:hi]
    # Phase 2: ddmin — remove halving chunks while the failure persists.
    chunk = max(1, len(best) // 2)
    while chunk >= 1:
        i = 0
        while i < len(best):
            candidate = best[:i] + best[i + chunk:]
            if fails(candidate):
                best = candidate
            else:
                i += chunk
        chunk //= 2
    return tuple(best)


# ---------------------------------------------------------------------------
# Schedule (de)serialization — the tests/schedules/ replay corpus format.
# ---------------------------------------------------------------------------
def save_schedule(path, *, scenario: str, schedule: Sequence[int],
                  expect: str, note: str = "",
                  seed: Optional[int] = None) -> None:
    rec = {"scenario": scenario, "schedule": list(schedule),
           "expect": expect, "note": note}
    if seed is not None:
        rec["seed"] = seed
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")


def load_schedule(path) -> dict:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("expect") not in ("pass", "violation"):
        raise ValueError(f"{path}: expect must be 'pass' or 'violation'")
    return rec
