"""Host-side lock-free queues composed from NBBs.

The paper (via Kim'07) notes the NBB "can be composed to support complex
communication patterns including publish/subscribe and broadcast".  We
compose:

  * :class:`SpscQueue` — thin alias over :class:`repro.core.nbb.HostNBB`.
  * :class:`MpscQueue` — N producers fan into one consumer via N private
    SPSC rings drained round-robin.  Each ring keeps the single-writer
    invariant, so the composition stays lock-free end to end (this is the
    MCAPI "multiple client endpoints -> one server receive queue" topology
    of the paper's Figure 1, without its global lock).
  * :class:`LockedQueue` — the *lock-based baseline* the paper measures
    against: a deque guarded by one mutex, standing in for the MCAPI
    reference implementation's global reader/writer lock.

All of them implement the unified Transport protocol
(``repro.core.transport``): ``send`` / ``try_recv`` / ``drain`` with
Table-1 status codes, so channels and engines are written against one
surface regardless of which queue backs them.

Framework uses: the data pipeline feeds the trainer through an MpscQueue;
the serving engine's slot-swap batcher drains client SPSC rings; the async
checkpointer receives snapshots through an SPSC ring.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, List, Optional, Tuple

from repro.core import interleave as _il
from repro.core import nbb, transport
from repro.core.nbb import HostNBB

SpscQueue = HostNBB


class MpscQueue:
    """Multi-producer single-consumer lock-free queue (fan-in of SPSC NBBs)."""

    def __init__(self, nproducers: int, capacity_per_producer: int = 64):
        self._rings: List[HostNBB] = [
            HostNBB(capacity_per_producer) for _ in range(nproducers)
        ]
        self._cursor = 0  # consumer-owned round-robin cursor

    def producer(self, i: int) -> HostNBB:
        """The private SPSC ring for producer ``i`` (single-writer)."""
        return self._rings[i]

    @property
    def n_producers(self) -> int:
        return len(self._rings)

    def pending(self) -> bool:
        """Consumer-side emptiness probe: True iff some producer ring
        holds a COMMITTED item right now.  Uses the rings' ``__len__``
        (uc//2 - ac//2 snapshot), which only counts committed inserts —
        safe for the single consumer to branch on (a concurrent insert
        can only turn False stale, never True)."""
        return any(len(r) for r in self._rings)

    def insert_item(self, producer_id: int, item: Any) -> int:
        return self._rings[producer_id].insert_item(item)

    def read_item(self) -> Tuple[int, Optional[Any]]:
        """Drain round-robin; returns first available item.  EMPTY only when
        every producer ring is empty this pass."""
        n = len(self._rings)
        busy = False
        for off in range(n):
            ring = self._rings[(self._cursor + off) % n]
            if _il._active is not None:
                _il._active.yield_point(
                    "mpsc.scan", (id(self), (self._cursor + off) % n))
            status, item = ring.read_item()
            if status == nbb.OK:
                self._cursor = (self._cursor + off + 1) % n
                return nbb.OK, item
            if status == nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING:
                busy = True
        return (nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING if busy
                else nbb.BUFFER_EMPTY), None

    # -- Transport protocol (consumer side) ----------------------------------
    # Producers are NOT funneled through a shared ``send`` — each producer
    # owns its private SPSC ring (``producer(i)``, itself a Transport),
    # which is what keeps the composition lock-free.
    try_recv = read_item

    def recv_i(self) -> transport.OpHandle:
        """Consumer-side non-blocking receive handle.  (No ``send_i``:
        producers hold their private rings, each a full Transport.)"""
        return transport.recv_i(self)

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return transport.drain(self, max_items)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        """Packet-mode fan-in drain: one span reservation per producer
        ring, visited in round-robin order from the cursor.  Per-producer
        FIFO is preserved (each ring is drained as one contiguous span);
        global order is round-robin by ring, as for scalar reads."""
        out: List[Any] = []
        n = len(self._rings)
        for off in range(n):
            take = None if max_n is None else max_n - len(out)
            if take is not None and take <= 0:
                break
            if _il._active is not None:
                _il._active.yield_point(
                    "mpsc.burst.scan", (id(self), (self._cursor + off) % n))
            out.extend(self._rings[(self._cursor + off) % n]
                       .drain_burst(take))
        if n:
            self._cursor = (self._cursor + 1) % n
        return out

    def get(self) -> Any:
        status, item = transport.recv_blocking(self)
        assert status == nbb.OK
        return item


class BroadcastChannel:
    """One producer -> N consumers, each with a private SPSC ring.

    Kim'07's composition claim (quoted in the paper §2): the NBB "can be
    composed to support complex communication patterns including
    publish/subscribe and broadcast connections".  Every consumer gets
    every item; the producer's insert is non-blocking per ring and
    reports the per-consumer status vector (a slow consumer only stalls
    itself — slot disjointness holds per ring).
    """

    def __init__(self, nconsumers: int, capacity: int = 64):
        self._rings: List[HostNBB] = [HostNBB(capacity)
                                      for _ in range(nconsumers)]

    def insert_item(self, item: Any) -> List[int]:
        return [ring.insert_item(item) for ring in self._rings]

    def publish(self, item: Any) -> None:
        pending = set(range(len(self._rings)))
        backoff = transport.Backoff()
        while pending:
            for i in list(pending):
                if self._rings[i].insert_item(item) == nbb.OK:
                    pending.discard(i)
            if pending:
                backoff.wait(nbb.BUFFER_FULL)
            else:
                backoff.reset()

    def consumer(self, i: int) -> HostNBB:
        return self._rings[i]


class LockedQueue:
    """Mutex-guarded FIFO — the paper's lock-based baseline.

    Mirrors the MCAPI reference design: every insert/read takes the one lock,
    serializing all access to the shared structure.  Capacity-bounded to
    match NBB semantics (returns the same status codes for comparability).

    ``blocking=True`` makes put/get park on condition variables (kernel
    futex wait + context switch) — the reference implementation's actual
    behavior, and the convoy cost the paper measures.  The default spins
    with yield, a *more* charitable lock-based baseline.
    """

    def __init__(self, capacity: int, blocking: bool = False):
        self._capacity = capacity
        self._dq: deque = deque()
        self._lock = threading.Lock()
        self._blocking = blocking
        if blocking:
            self._not_full = threading.Condition(self._lock)
            self._not_empty = threading.Condition(self._lock)

    def insert_item(self, item: Any) -> int:
        with self._lock:
            if len(self._dq) >= self._capacity:
                return nbb.BUFFER_FULL
            self._dq.append(item)
            return nbb.OK

    def read_item(self) -> Tuple[int, Optional[Any]]:
        with self._lock:
            if not self._dq:
                return nbb.BUFFER_EMPTY, None
            return nbb.OK, self._dq.popleft()

    def send_burst(self, vals) -> Tuple[int, int]:
        """Burst insert under the one lock — the packet-mode baseline:
        the copy is amortized but every burst still serializes behind
        the same mutex the scalar ops take."""
        if not len(vals):               # NBB parity: empty burst is OK
            return nbb.OK, 0
        with self._lock:
            space = self._capacity - len(self._dq)
            if space <= 0:
                return nbb.BUFFER_FULL, 0
            m = min(space, len(vals))
            self._dq.extend(vals[i] for i in range(m))
            if self._blocking and m:
                self._not_empty.notify_all()
            return (nbb.OK, m) if m == len(vals) else (nbb.BUFFER_FULL, m)

    def drain_burst(self, max_n: Optional[int] = None) -> List[Any]:
        with self._lock:
            m = len(self._dq) if max_n is None else min(max_n, len(self._dq))
            out = [self._dq.popleft() for _ in range(max(m, 0))]
            if self._blocking and out:
                self._not_full.notify_all()
            return out

    # Transport protocol: the baseline speaks the same surface, so the A/B
    # benchmark swaps implementations without touching caller code.
    send = insert_item
    try_recv = read_item

    def send_i(self, payload: Any) -> transport.OpHandle:
        return transport.send_i(self, payload)

    def recv_i(self) -> transport.OpHandle:
        return transport.recv_i(self)

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        return transport.drain(self, max_items)

    def put(self, item: Any) -> None:
        if self._blocking:
            with self._not_full:
                while len(self._dq) >= self._capacity:
                    self._not_full.wait()
                self._dq.append(item)
                self._not_empty.notify()
            return
        transport.send_blocking(self, item)

    def get(self) -> Any:
        if self._blocking:
            with self._not_empty:
                while not self._dq:
                    self._not_empty.wait()
                item = self._dq.popleft()
                self._not_full.notify()
                return item
        status, item = transport.recv_blocking(self)
        assert status == nbb.OK
        return item
