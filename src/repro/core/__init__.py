"""Core lock-free communication library (the paper's contribution).

Modules:
  nbb        — Non-Blocking Buffer (event messages, SPSC FIFO ring)
  nbw        — Non-Blocking Write protocol (state messages)
  bitset     — lock-free slot allocator (replaces lock-free linked lists)
  refcount   — refcounted generalization of the bitset (shared KV pages)
  states     — CAS finite-state machines for request/buffer lifecycles
  host_queue — SPSC/MPSC compositions + the lock-based baseline
  transport  — unified send/try_recv/drain protocol + Table-1 backoff
  channels   — MCAPI-style domains/nodes/endpoints/channels (host + device)
"""
from repro.core import (bitset, channels, host_queue, nbb, nbw,  # noqa: F401
                        refcount, states, transport)
