"""Lock-free bit set — request/slot allocation without a linked list.

Refactoring step (3) of the paper: the lock-free *doubly linked list* used to
track asynchronous request objects was replaced by a lock-free *bit set*,
because lock-free doubly-linked lists are not feasible in practice
([25][26] in the paper).  A bit set supports the only two operations the
request pool needs — claim-any-free-slot and release-slot — with single-word
atomics.

Host variant: CPython's ``dict.setdefault`` is an atomic compare-and-swap
(single bytecode under the GIL), which gives a genuine lock-free test-and-set
per slot.  Used for KV-cache page allocation and in-flight request tracking
in the serving engine.

JAX variant: functional claim/release over a packed uint32 word array, for
allocator state carried through jitted loops.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import interleave as _il


class HostBitset:
    """Lock-free slot allocator for host threads (multi-producer safe)."""

    __slots__ = ("_n", "_claims")

    def __init__(self, nslots: int):
        self._n = nslots
        # slot -> owner token.  dict.setdefault is our CAS primitive.
        self._claims: dict = {}

    @property
    def capacity(self) -> int:
        return self._n

    def try_claim(self, owner: object = True, start: int = 0) -> Optional[int]:
        """Claim any free slot; returns its index or None when all taken.

        Lock-free: each probe is one atomic setdefault; a failed probe means
        another thread won that slot and we move on (the paper's "progress in
        finite time" guarantee — someone always succeeds).
        """
        n = self._n
        for off in range(n):
            i = (start + off) % n
            if _il._active is not None:
                _il._active.yield_point("bitset.probe", (id(self), i))
            if self._claims.setdefault(i, owner) is owner:
                return i
        return None

    def claim_specific(self, i: int, owner: object = True) -> bool:
        if _il._active is not None:
            _il._active.yield_point("bitset.probe", (id(self), i))
        return self._claims.setdefault(i, owner) is owner

    def release(self, i: int) -> None:
        if _il._active is not None:
            _il._active.yield_point("bitset.release", (id(self), i))
        # pop() is atomic; releasing an unclaimed slot is a programming error.
        if self._claims.pop(i, _MISSING) is _MISSING:
            raise KeyError(f"slot {i} was not claimed")

    def is_claimed(self, i: int) -> bool:
        return i in self._claims

    def count(self) -> int:
        return len(self._claims)


_MISSING = object()


# ---------------------------------------------------------------------------
# Functional JAX variant: words of 32 slots each.
# ---------------------------------------------------------------------------
def init(nslots: int) -> jnp.ndarray:
    nwords = (nslots + 31) // 32
    return jnp.zeros((nwords,), jnp.uint32)


def claim_first_free(bits: jnp.ndarray, nslots: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Claim the lowest free slot.  Returns (new_bits, slot) with slot == -1
    when the set is full (caller branches on it, never blocks)."""
    nwords = bits.shape[0]
    lanes = jnp.arange(32, dtype=jnp.uint32)
    free = (bits[:, None] >> lanes[None, :]) & jnp.uint32(1) == 0  # [w, 32]
    idx = jnp.arange(nwords * 32).reshape(nwords, 32)
    valid = free & (idx < nslots)
    flat = valid.reshape(-1)
    slot = jnp.argmax(flat)  # first True, or 0 if none
    any_free = jnp.any(flat)
    slot = jnp.where(any_free, slot, -1)
    word, lane = slot // 32, slot % 32
    new_bits = jnp.where(
        any_free,
        bits.at[word].set(bits[word] | (jnp.uint32(1) << lane.astype(jnp.uint32))),
        bits,
    )
    return new_bits, slot.astype(jnp.int32)


def release(bits: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    word, lane = slot // 32, slot % 32
    mask = ~(jnp.uint32(1) << lane.astype(jnp.uint32))
    return bits.at[word].set(bits[word] & mask)


def is_claimed(bits: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    word, lane = slot // 32, slot % 32
    return ((bits[word] >> lane.astype(jnp.uint32)) & jnp.uint32(1)) == 1


def count(bits: jnp.ndarray) -> jnp.ndarray:
    lanes = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(((bits[:, None] >> lanes[None, :]) & jnp.uint32(1)).astype(jnp.int32))
