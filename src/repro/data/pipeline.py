"""Synthetic token pipeline through the lock-free host queues.

Producer threads synthesize token batches (seeded, reproducible) and
insert them into their private SPSC rings of an :class:`MpscQueue`; the
trainer drains the fan-in.  This is the paper's Figure-1 topology
(client producer endpoints -> server consumer FIFO) with the global lock
deleted — host-side data feeding is a real concurrency domain even in a
JAX program (input pipeline vs. dispatch vs. checkpoint writer threads).

The stream is *deterministic per (seed, producer, sequence-number)*, so a
restart that re-feeds from step N reproduces the exact batches — the data
side of the checkpoint/restart contract.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import nbb, transport
from repro.core.host_queue import MpscQueue


def synth_batch(seed: int, producer: int, seq_no: int, batch: int,
                seq_len: int, vocab: int,
                extras_shape: Optional[tuple] = None) -> Dict[str, np.ndarray]:
    """Deterministic synthetic batch (Zipf-ish token distribution)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, producer, seq_no]))
    # Zipf over vocab, clipped — cheap stand-in for natural token stats.
    z = rng.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
    tokens = (z % vocab).astype(np.int32)
    out = {"tokens": tokens}
    if extras_shape is not None:
        out["extras"] = rng.standard_normal(
            (batch,) + tuple(extras_shape)).astype(np.float32)
    return out


class DataPipeline:
    """N producer threads -> lock-free MPSC ring -> trainer.

    get() returns batches in a deterministic global order is NOT promised
    (MPSC fan-in is round-robin, matching event-message semantics); what
    is promised is every produced batch is consumed exactly once and each
    producer's sub-stream is FIFO (the NBB guarantee).
    """

    def __init__(self, batch: int, seq_len: int, vocab: int,
                 nproducers: int = 2, seed: int = 0, depth: int = 8,
                 extras_shape: Optional[tuple] = None):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab
        self.seed, self.extras_shape = seed, extras_shape
        self._queue = MpscQueue(nproducers, capacity_per_producer=depth)
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=self._produce, args=(i,), daemon=True)
            for i in range(nproducers)
        ]
        for t in self._threads:
            t.start()

    def _produce(self, pid: int) -> None:
        ring = self._queue.producer(pid)
        seq_no = 0
        while not self._stop.is_set():
            item = synth_batch(self.seed, pid, seq_no, self.batch,
                               self.seq_len, self.vocab, self.extras_shape)
            # Table-1 retry protocol via the shared Transport backoff:
            # spin on transient statuses, yield, then exponential sleep.
            transport.send_blocking(ring, item,
                                    should_stop=self._stop.is_set)
            seq_no += 1

    def get(self) -> Dict[str, np.ndarray]:
        status, item = transport.recv_blocking(self._queue)
        assert status == nbb.OK
        return item

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.get()

    def close(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
