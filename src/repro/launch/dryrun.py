import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices to build the
production meshes.  Smoke tests and benches must NOT import this module.

For each cell this produces:
  * compiled.memory_analysis()  — proves the program fits 16 GB/chip,
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes,
  * collective bytes parsed from the optimized HLO (per collective kind),
all recorded as JSON under results/dryrun/ for the roofline stage.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-train-4k]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import get_config, list_archs, shapes_for
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.models.inputs import decode_specs, prefill_specs, train_batch_specs
from repro.models.model import build_model
from repro.parallel import sharding as shlib
from repro.train.optimizer import AdamW, OptConfig
from repro.train.train_step import (batch_shardings, cache_shardings,
                                    make_decode_step, make_train_step,
                                    opt_state_shardings, param_shardings)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Per-device view: each op line looks like
      %x = bf16[8,128,7168]{...} all-gather(...)
    We count the op's result size (bytes leaving/entering this device's
    link domain); tuples are summed over members.
    """
    dtype_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                   "u32": 4, "s64": 8, "u64": 8, "s8": 1, "u8": 1,
                   "pred": 1, "s16": 2, "u16": 2}
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(\(?[\w\[\],\s{}/#*_-]+?\)?)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(m.group(1)):
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dtype_bytes[dt]
        totals[kind] += nbytes
        counts[kind] += 1
    return totals, counts


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                opt_cfg: OptConfig = None, remat: str = "nothing",
                rules_override=None, microbatches: int = 1,
                verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, remat_policy=remat)
    if opt_cfg is None:
        # arctic-480B needs 8-bit optimizer state to fit (DESIGN.md).
        state_dtype = "int8" if arch == "arctic-480b" else "float32"
        opt_cfg = OptConfig(state_dtype=state_dtype)
    opt = AdamW(opt_cfg)

    rules = dict(cfg.mesh_rules or {})
    if rules_override:
        rules.update(rules_override)

    t0 = time.time()
    with shlib.axis_rules(mesh, rules), jax.set_mesh(mesh):
        params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_sh = param_shardings(model, mesh)

        if shape.kind == "train":
            batch_abs = train_batch_specs(cfg, shape)
            o_sh = opt_state_shardings(model, opt, mesh, params_abs)
            b_sh = batch_shardings(mesh, batch_abs)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            step = make_train_step(model, opt, microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            specs = prefill_specs(cfg, shape)
            b_sh = batch_shardings(mesh, specs)

            def prefill_fn(params, tokens, extras=None):
                return model.prefill(params, tokens, shape.seq_len,
                                     extras=extras)

            args = [params_abs, specs["tokens"]]
            in_sh = [p_sh, b_sh["tokens"]]
            if "extras" in specs:
                args.append(specs["extras"])
                in_sh.append(b_sh["extras"])
            lowered = jax.jit(prefill_fn, in_shardings=tuple(in_sh)).lower(*args)
        else:  # decode
            specs = decode_specs(cfg, shape, model)
            c_sh = cache_shardings(mesh, specs["caches"], cfg)
            t_sh = batch_shardings(mesh, {"t": specs["tokens"]})["t"]
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, t_sh, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs["caches"],
                                   specs["tokens"], specs["pos"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        acc = hlo_analysis.analyze(hlo)  # while-aware (xla counts loops once)
        coll_bytes = {k: acc.collective_bytes[k] for k in acc.collective_bytes}
        coll_counts = {k: acc.collective_counts[k]
                       for k in acc.collective_counts}

    n_devices = mesh.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices,
        "kind": shape.kind,
        "remat": remat,
        "microbatches": microbatches,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": acc.flops,
        "bytes_accessed_per_device": acc.bytes,
        "xla_flops_loop_body_once": cost.get("flops", 0.0),
        "xla_bytes_loop_body_once": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll_bytes,
        "collective_counts": coll_counts,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        peak_gb = result["memory"]["peak_estimate_bytes"] / 1e9
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"compile {t_compile:.0f}s, "
              f"{result['flops_per_device']/1e12:.2f} TF/dev, "
              f"peak ~{peak_gb:.2f} GB/dev, "
              f"colls {sum(coll_counts.values())}", flush=True)
    return result


def save(result, tag=""):
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}{tag}.json"
    (RESULTS / name).write_text(json.dumps(result, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = []
        cells = []
        for arch in list_archs():
            for shape in shapes_for(arch):
                cells.append((arch, shape.name, False))
                cells.append((arch, shape.name, True))
        for arch, shape, mp in cells:
            mesh_tag = "2x16x16" if mp else "16x16"
            out = RESULTS / f"{arch}__{shape}__{mesh_tag}.json"
            if args.skip_existing and out.exists():
                continue
            try:
                save(dryrun_cell(arch, shape, mp, remat=args.remat))
            except Exception as e:
                failures.append((arch, shape, mesh_tag, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} x {mesh_tag}: {e}",
                      flush=True)
                traceback.print_exc()
        print(f"\n[dryrun] done; {len(failures)} failures")
        for f in failures:
            print("  FAIL:", *f[:3])
        sys.exit(1 if failures else 0)
    else:
        result = dryrun_cell(args.arch, args.shape or "train_4k",
                             args.multi_pod, remat=args.remat)
        save(result)
        print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
