"""While-loop-aware HLO accounting for FLOPs, bytes and collective traffic.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
that scans over layers (all of ours) is undercounted by ~num_layers.  This
module parses the optimized HLO text, builds the computation call graph, and
multiplies loop bodies by their trip count (recovered from the loop
condition's comparison constant).

Accounting rules (per-device, since SPMD-partitioned HLO is per-device):
  * flops      — 2*|out|*K for dot ops (K = contracted size), plus
                 convolution as 2*|out|*K_window.  Elementwise ops are
                 ignored (<2% for transformer workloads).
  * bytes      — operands + outputs of memory-touching top-level ops
                 (fusion boundaries = HBM round-trips; calls recursed).
  * collectives— result bytes per op, split by kind.

Everything is exact for the op kinds that matter and deliberately
approximate elsewhere; the roofline needs 2 significant figures.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "token": 0, "opaque": 0}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    shape: str          # result shape string
    kind: str           # opcode
    operands: List[str]
    attrs: str          # full remainder of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _parse_op_line(line: str) -> Optional[Tuple[str, str, str, str, str]]:
    """'  %name = SHAPE kind(operands), attrs' -> parts (balanced parens,
    tolerant of /*index=N*/ comments inside tuple shapes)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s.startswith("%") and not s[0].isalpha():
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3:]
    # shape: balanced (...) tuple or a token up to the following space
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape, rest = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest = rest[:sp], rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    kind = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", kind):
        return None
    depth, j = 0, par
    for j in range(par, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = rest[par + 1:j]
    attrs = rest[j + 1:]
    return name, shape, kind, operands, attrs


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and ("=" not in stripped.split("(")[0]):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                # header params: "(p0: f32[2,3], p1: s32[4]) -> ..." — record
                # them as parameter ops so operand names resolve to shapes
                hdr = stripped[stripped.find("(") + 1:]
                hdr = hdr[:hdr.find(")")] if ")" in hdr else hdr
                for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*"
                                      r"((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)",
                                      hdr):
                    pop = Op(pm.group(1), pm.group(2), "parameter", [], "")
                    cur.ops[pm.group(1)] = pop
                    cur.order.append(pm.group(1))
                continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, shape, kind, operands, attrs = parsed
        opnds = []
        depth = 0
        tok = ""
        for ch in operands:
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            if ch == "," and depth == 0:
                opnds.append(tok.strip())
                tok = ""
            else:
                tok += ch
        if tok.strip():
            opnds.append(tok.strip())
        op = Op(name, shape.strip(), kind, opnds, attrs)
        cur.ops[name] = op
        cur.order.append(name)
    return comps, entry


def _called_comps(op: Op) -> List[str]:
    """Computation names referenced by this op (calls/fusion/while/etc)."""
    out = []
    for key in ("to_apply=", "calls=", "condition=", "body=",
                "true_computation=", "false_computation="):
        for m in re.finditer(re.escape(key) + r"%?([\w.\-]+)", op.attrs):
            out.append(m.group(1))
    # branch_computations={%a, %b}
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _while_trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    """Trip count of a while op.

    Primary source: XLA's own loop analysis, which stamps
    ``backend_config={"known_trip_count":{"n":"N"}}`` on the optimized
    while op — exact for every canonical jax scan/fori loop.  Fallback
    (unoptimized HLO in unit tests): largest integer constant in the
    condition computation."""
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    cond_name = re.search(r"condition=%?([\w.\-]+)", op.attrs)
    if not cond_name or cond_name.group(1) not in comps:
        return 1
    best = 1
    for cop in comps[cond_name.group(1)].ops.values():
        if cop.kind == "constant":
            mc = re.search(r"constant\((\d+)\)",
                           "constant(" + ",".join(cop.operands) + ")"
                           + cop.attrs)
            if mc:
                best = max(best, int(mc.group(1)))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.shape)
    # contraction size: product of lhs contracting dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_ref = op.operands[0].split(" ")[-1].lstrip("%")
    lhs_shape = None
    if lhs_ref in comp.ops:
        lhs_shape = comp.ops[lhs_ref].shape
    else:
        sm = _SHAPE_RE.search(op.operands[0])
        lhs_shape = sm.group(0) if sm else None
    k = 1
    if lhs_shape:
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
            for c in cdims:
                if c < len(dims):
                    k *= dims[c]
    return 2.0 * out_elems * k


# Ops whose operands AND outputs hit HBM even after TPU fusion.
_MEMORY_OPS = {"dot", "convolution", "fusion", "custom-call", "copy",
               "transpose", "reduce", "scatter", "gather", "dynamic-slice",
               "dynamic-update-slice", "concatenate", "slice", "pad", "sort",
               "reduce-window", "select-and-scatter"}
# Elementwise/layout ops would be fused into neighbours on TPU: count their
# output once (value written once, read by consumer counted there).
_OUTPUT_ONLY_OPS = {"add", "subtract", "multiply", "divide", "convert",
                    "broadcast", "select", "compare", "tanh", "exponential",
                    "log", "rsqrt", "sqrt", "maximum", "minimum", "negate",
                    "abs", "power", "and", "or", "not", "xor", "clamp",
                    "iota", "reshape", "bitcast", "sign", "floor", "ceil",
                    "round-nearest-even", "logistic", "cosine", "sine"}


@dataclasses.dataclass
class Account:
    flops: float = 0.0
    bytes: float = 0.0       # pessimistic: + every elementwise output (unfused)
    bytes_min: float = 0.0   # optimistic: perfect elementwise fusion on TPU
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def add(self, other: "Account", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        for k in COLLECTIVE_KINDS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult


def analyze(text: str) -> Account:
    """Walk the call graph from ENTRY.

    Accounting discipline (per-device):
      * FLOPs: dot/convolution ops wherever they appear (including inside
        fusions), multiplied by enclosing while trip counts.
      * Bytes: operands + outputs of *fusion-boundary* ops only — a fused
        computation's internal ops live in registers/VMEM on TPU, so the
        HBM traffic of a fusion is its operands and outputs, not its body.
        Free layout ops (bitcast/reshape/get-tuple-element/tuple/
        parameter/constant) cost nothing; while/call/conditional recurse.
      * Collectives: result bytes per op kind, trip-count aware.
    """
    comps, entry = parse_hlo(text)
    cache: Dict[Tuple[str, bool], Account] = {}

    _FREE = {"bitcast", "reshape", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "token", "partition-id", "replica-id",
             "copy-done", "all-gather-done", "all-reduce-done",
             "collective-permute-done", "opt-barrier"}
    _CTRL = {"call", "conditional", "map"}

    def _operand_shape_bytes(o: str, comp: Optional[Computation]) -> int:
        """Bytes of one operand string; bare %names resolve via comp."""
        sm = _SHAPE_RE.search(o)
        if sm:
            return _shape_bytes(sm.group(0))
        if comp is not None:
            ref = o.strip().split(" ")[-1].lstrip("%")
            if ref in comp.ops:
                return _shape_bytes(comp.ops[ref].shape)
        return 0

    def _op_bytes(op: Op, comp: Optional[Computation] = None) -> float:
        b = _shape_bytes(op.shape)
        for o in op.operands:
            b += _operand_shape_bytes(o, comp)
        return b

    def _root_op(comp_name: str) -> Optional[Op]:
        comp = comps.get(comp_name)
        if not comp or not comp.order:
            return None
        return comp.ops[comp.order[-1]]

    def _fusion_bytes(op: Op, comp: Optional[Computation],
                      in_loop: bool = False) -> float:
        """HBM traffic of a fusion = boundary operands + result, EXCEPT
        in-place slice updates: a fusion whose root is dynamic-update-
        slice writes only the update region (XLA aliases the carried
        buffer), and a dynamic-slice root reads only the slice.  Without
        this, a scan that updates one [16,5,64,64] slot of a [4097,...]
        stacked buffer is charged 5.4 GB/trip instead of 1.3 MB/trip —
        a 4000x overcount observed on the zamba2 SSD cell."""
        operand_bytes = [_operand_shape_bytes(o, comp) for o in op.operands]
        result_b = _shape_bytes(op.shape)
        full_b = result_b
        root = None
        sub_comp = None
        for sub in _called_comps(op):
            r = _root_op(sub)
            if r is not None:
                root, sub_comp = r, comps.get(sub)
        if root is not None and root.kind in ("dynamic-update-slice",
                                              "dynamic-slice"):
            if root.kind == "dynamic-update-slice":
                # update operand = root's 2nd arg; read+write the region
                upd = 0
                if len(root.operands) > 1:
                    upd = _operand_shape_bytes(root.operands[1], sub_comp)
                result_b = 2 * upd if upd else result_b
                # drop the aliased full-size carried operand
                for i, ob in enumerate(operand_bytes):
                    if ob == full_b:
                        operand_bytes[i] = 0
                        break
            else:
                # dynamic-slice: result is the slice; drop the big source
                for i, ob in enumerate(operand_bytes):
                    if ob > 8 * result_b:
                        operand_bytes[i] = 0
                        break
        if in_loop:
            # Inside a while body, an operand vastly larger than the
            # fusion's result is a loop-carried buffer accessed through
            # an internal dynamic-slice (backward reads of scan-stacked
            # state): charge it at result granularity, not full size.
            cap = max(result_b, 1)
            operand_bytes = [ob if ob <= 8 * cap else cap
                             for ob in operand_bytes]
        return result_b + sum(operand_bytes)

    def _conv_k(op: Op, comp: Computation) -> int:
        # window size x input channels from the rhs (kernel) shape
        rhs_ref = op.operands[1].split(" ")[-1].lstrip("%") \
            if len(op.operands) > 1 else ""
        if rhs_ref in comp.ops:
            sm = _SHAPE_RE.search(comp.ops[rhs_ref].shape)
            if sm:
                dims = [int(x) for x in sm.group(2).split(",") if x]
                n = 1
                for d in dims[:-1]:
                    n *= d
                return n
        return 1

    def comp_account(name: str, in_fusion: bool, stack=(),
                     in_loop: bool = False) -> Account:
        key = (name, in_fusion, in_loop)
        if key in cache:
            return cache[key]
        if name in stack or name not in comps:
            return Account()
        comp = comps[name]
        acc = Account()
        for opname in comp.order:
            op = comp.ops[opname]
            kind = op.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            if kind == "dot":
                acc.flops += _dot_flops(op, comp)
                if not in_fusion:
                    acc.bytes += _op_bytes(op, comp)
                    acc.bytes_min += _op_bytes(op, comp)
            elif kind == "convolution":
                acc.flops += 2.0 * _shape_elems(op.shape) * _conv_k(op, comp)
                if not in_fusion:
                    acc.bytes += _op_bytes(op, comp)
                    acc.bytes_min += _op_bytes(op, comp)
            elif base in COLLECTIVE_KINDS:
                b = _shape_bytes(op.shape)
                acc.collective_bytes[base] += b
                acc.collective_counts[base] += 1
                if not in_fusion:
                    acc.bytes += _op_bytes(op, comp)
                    acc.bytes_min += _op_bytes(op, comp)
            elif kind == "while":
                body_name = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if body_name and body_name.group(1) in comps:
                    trips = _while_trip_count(op, comps)
                    inner = comp_account(body_name.group(1), in_fusion,
                                         stack + (name,), True)
                    acc.add(inner, trips)
            elif kind == "fusion":
                # flops from fused dots; bytes only at the boundary
                for sub in _called_comps(op):
                    inner = comp_account(sub, True, stack + (name,),
                                         in_loop)
                    acc.flops += inner.flops
                    for k in COLLECTIVE_KINDS:
                        acc.collective_bytes[k] += inner.collective_bytes[k]
                        acc.collective_counts[k] += inner.collective_counts[k]
                if not in_fusion:
                    fb = _fusion_bytes(op, comp, in_loop)
                    acc.bytes += fb
                    acc.bytes_min += fb
            elif kind in _CTRL:
                for sub in _called_comps(op):
                    acc.add(comp_account(sub, in_fusion, stack + (name,),
                                         in_loop))
            elif kind in ("reduce", "sort", "scatter", "select-and-scatter",
                          "custom-call"):
                # to_apply bodies are tiny combinators; count the boundary
                if not in_fusion:
                    acc.bytes += _op_bytes(op, comp)
                    acc.bytes_min += _op_bytes(op, comp)
            elif kind == "dynamic-update-slice":
                if not in_fusion:
                    # in-place region write: read+write the update only
                    upd = 0
                    if len(op.operands) > 1:
                        sm = _SHAPE_RE.search(op.operands[1])
                        if sm:
                            upd = _shape_bytes(sm.group(0))
                    b = 2 * upd if upd else _shape_bytes(op.shape)
                    acc.bytes += b
                    acc.bytes_min += b
            elif kind in _FREE:
                pass
            elif not in_fusion:
                # any other top-level op reads/writes HBM once
                acc.bytes += _op_bytes(op, comp)
                acc.bytes_min += _shape_bytes(op.shape)
        cache[key] = acc
        return acc

    if entry is None and comps:
        entry = next(iter(comps))
    return comp_account(entry, False) if entry else Account()
