"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Composes the full stack: config registry -> model -> sharded train step
(on the active mesh) -> lock-free data pipeline -> Trainer (checkpoint/
restart, straggler detection, NBW telemetry).

On this CPU container run smoke-size archs (``--smoke``); on a TPU fleet
drop ``--smoke`` and pass ``--mesh single|multi`` to get the production
mesh of DESIGN.md §7 (the dry-run proves every full config compiles).
"""
from __future__ import annotations

import argparse


from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.pipeline import DataPipeline
from repro.models.model import build_model
from repro.parallel import sharding as shlib
from repro.train.optimizer import AdamW, OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> Trainer:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="nothing",
                    choices=["nothing", "dots", "none"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"],
                    help="production mesh (requires >= 256 devices)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, remat_policy=args.remat)
    opt = AdamW(OptConfig(lr=args.lr, total_steps=args.steps))

    ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = shlib.axis_rules(mesh, cfg.mesh_rules or {})
        ctx.__enter__()

    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    trainer = Trainer(model, opt, tc, resume=args.resume)
    pipe = DataPipeline(batch=args.batch, seq_len=args.seq,
                        vocab=cfg.vocab_size, nproducers=2)
    try:
        hist = trainer.fit(
            pipe, steps=args.steps,
            on_metrics=lambda s, m: print(
                f"step {s:5d}  loss {m['loss']:.4f}  "
                f"gnorm {m['grad_norm']:.2f}  {m['dt_s'] * 1e3:.0f} ms",
                flush=True))
    finally:
        pipe.close()
        trainer.close()
        if ctx:
            ctx.__exit__(None, None, None)
    print(f"done: {trainer.step} steps, final loss "
          f"{hist[-1]['loss']:.4f}, stragglers {trainer.straggler_steps}")
    return trainer


if __name__ == "__main__":
    main()
