"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Starts the lock-free ServeEngine and drives it with synthetic client
threads over the streaming session API: each client owns a Session,
submits through non-blocking ``submit_i`` handles, and consumes tokens
as they are produced via ``RequestHandle.tokens()``.  Per-client results
travel back to the main thread over private SPSC rings drained through
the Transport protocol — no lock anywhere in the demo, matching the
engine it demonstrates.  Prints throughput, completion latency, TTFT,
and the engine's lock-free stats.
"""
from __future__ import annotations

import argparse
import os
import signal
import threading
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core import nbb
from repro.core.host_queue import SpscQueue
from repro.models.model import build_model
from repro.serve.engine import ServeEngine
from repro.serve.overload import (
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    OverloadPolicy,
)


def _pct(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return float("nan")
    return sorted_vals[min(int(len(sorted_vals) * q), len(sorted_vals) - 1)]


def main(argv=None) -> ServeEngine:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--requests-per-client", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="decode slots (default 8 for slot_paged — paged "
                         "residency is length-proportional, so slots are "
                         "cheap — else 4)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--scheduler", default=None,
                    choices=["slot_paged", "slot_chunked", "slot_fused",
                             "slot", "wave"],
                    help="slot_paged = chunked admission + fused decode "
                         "with the page pool as the device-resident KV "
                         "store (block-table indirection, zero-copy "
                         "residency; falls back to slot_chunked/"
                         "slot_fused for non-pageable archs); "
                         "slot_chunked = chunked zero-copy admission fused "
                         "into the decode micro-batch (default; falls back "
                         "to slot_fused for recurrent-state archs); "
                         "slot_fused = packet-mode fused K-step decode; "
                         "slot = per-token iteration-level batching; "
                         "wave = batch-level baseline")
    ap.add_argument("--k-max", type=int, default=8,
                    help="max fused decode steps per block (slot_fused/"
                         "slot_chunked)")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="prompt tokens streamed per dispatch "
                         "(slot_chunked)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the shared-prefix page cache "
                         "(slot_paged): every prompt prefills cold even "
                         "when its prefix KV is already resident")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="tokens of a common system prompt prepended to "
                         "every request (exercises the prefix cache)")
    ap.add_argument("--priorities", action="store_true",
                    help="enable the overload-control subsystem: requests "
                         "carry a priority class (~20%% high / 60%% normal "
                         "/ 20%% low) and intake serves classes strictly "
                         "with aging (DESIGN.md §12)")
    ap.add_argument("--preemption", action="store_true",
                    help="let a high-priority arrival preempt a running "
                         "low-priority slot by swapping its private KV "
                         "pages to host (slot_paged only; implies "
                         "--priorities)")
    ap.add_argument("--wfq", action="store_true",
                    help="weighted-fair queuing across clients inside "
                         "each priority class (implies --priorities)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="admission SLO: shed any request that waited "
                         "longer than this in the intake before binding "
                         "(implies --priorities)")
    ap.add_argument("--lease-s", type=float, default=None,
                    help="per-session lease: a client silent (no pump, "
                         "no submit) longer than this is presumed dead — "
                         "its in-flight requests fail with a typed "
                         "terminal and its slots/pages/rings are "
                         "reclaimed (DESIGN.md §13)")
    ap.add_argument("--tick-retries", type=int, default=1,
                    help="whole-tick retries the watchdog grants a "
                         "transient dispatch fault before failing the "
                         "bound slots (DESIGN.md §13)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="arm crash recovery (slot_paged only): "
                         "crash-consistent engine snapshots + a "
                         "write-ahead intake journal land here; "
                         "SIGINT/SIGTERM snapshot before exiting "
                         "(DESIGN.md §14)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="periodic snapshot cadence in engine ticks "
                         "(default: only at shutdown/crash)")
    ap.add_argument("--restore", default=None, metavar="PATH",
                    help="restore before serving: a snapshot file, or a "
                         "snapshot directory (newest valid snapshot + "
                         "journal replay); prints the restore report")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scheduler = args.scheduler
    if scheduler is None:
        # Chunked admission needs position-indexed caches; recurrent
        # archs (mamba/rwkv) keep the fused monolithic-prefill default.
        scheduler = "slot_chunked" if model.chunkable else "slot_fused"
    if scheduler == "slot_paged" and not model.pageable:
        # Paged residency needs one uniform position-indexed KV shape.
        fallback = "slot_chunked" if model.chunkable else "slot_fused"
        print(f"{cfg.name}: not pageable, falling back to {fallback}")
        scheduler = fallback
    # Paged residency is length-proportional, so decode slots are cheap:
    # the paged default doubles the slot pool on the same HBM budget.
    max_batch = args.max_batch or (8 if scheduler == "slot_paged" else 4)
    page_size = 16
    if scheduler == "slot_paged":
        # The pool IS the device KV store: size it to exactly the dense
        # batch cache's position budget (max_batch * max_len) so the
        # kv-memory report below compares equal allocations — for the
        # dense schedulers the pool is accounting only, and its page
        # count is pure admission headroom.
        pool_pages = (max_batch * args.max_len + page_size - 1) // page_size
    else:
        pool_pages = max(256, args.clients * 16)
    overload = None
    use_overload = (args.priorities or args.preemption or args.wfq
                    or args.slo_ms is not None)
    if use_overload:
        preemption = args.preemption
        if preemption and scheduler != "slot_paged":
            # Page-swap preemption needs the page pool as the KV store.
            print(f"{scheduler}: no page pool, disabling --preemption")
            preemption = False
        overload = OverloadPolicy(
            priorities=True, preemption=preemption, wfq=args.wfq,
            slo_s=None if args.slo_ms is None else args.slo_ms / 1e3)
    snapshot_dir = args.snapshot_dir
    if args.restore is not None and snapshot_dir is None:
        # --restore implies a snapshot home: the directory the snapshot
        # lives in (so the journal opens alongside it).
        snapshot_dir = (args.restore if os.path.isdir(args.restore)
                        else os.path.dirname(args.restore) or ".")
    if snapshot_dir is not None and scheduler != "slot_paged":
        print(f"{scheduler}: no paged KV state, disabling snapshots")
        snapshot_dir = None
    eng = ServeEngine(model, params, max_batch=max_batch,
                      max_len=args.max_len, n_clients=args.clients,
                      pool_pages=pool_pages, page_size=page_size,
                      scheduler=scheduler, k_max=args.k_max,
                      chunk_tokens=min(args.chunk_tokens, args.max_len),
                      prefix_cache=not args.no_prefix_cache,
                      overload=overload, lease_s=args.lease_s,
                      tick_retries=args.tick_retries,
                      snapshot_dir=snapshot_dir,
                      snapshot_every=args.snapshot_every)
    if args.restore is not None and snapshot_dir is not None:
        report = (eng.restore_latest() if os.path.isdir(args.restore)
                  else eng.restore(args.restore))
        if report is None:
            print(f"restore: no usable snapshot under {args.restore}, "
                  f"starting empty")
        else:
            print(f"restore: resumed {report['resumed']} requests, "
                  f"replayed {report['replayed']}, "
                  f"redelivered {report['redelivered']} terminals, "
                  f"failed {report['failed']} "
                  f"(from {report.get('path', args.restore)})")

    # Graceful shutdown (DESIGN.md §14): SIGINT/SIGTERM stop the serve
    # loop, whose exit path snapshots the final consistent state — the
    # handler itself only sets flags (signal-safe).  Previous handlers
    # are restored on the way out so embedding callers keep theirs.
    prev_handlers = {}

    def _graceful(signum, frame):
        eng.request_snapshot()
        eng.stop()

    if snapshot_dir is not None:
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                prev_handlers[sig] = signal.signal(sig, _graceful)
            except ValueError:
                pass                    # not the main thread: skip
    eng_thread = eng.start()

    # One private SPSC result ring per client (client thread produces,
    # main thread drains after join): the Figure-1 fan-in without its
    # lock, in the launcher itself.
    results = [SpscQueue(args.requests_per_client + 1)
               for _ in range(args.clients)]

    # Optional shared system prompt: identical across every client, so
    # with the prefix cache on only the first prefill pays for it.
    shared = (np.arange(args.shared_prefix_len) * 7 + 3) % cfg.vocab_size

    def client(c: int) -> None:
        rng = np.random.default_rng(c)
        # Context-managed session: in-flight handles are cancelled and
        # the client's rings drop cleanly even when a client thread dies
        # mid-run (the robustness the lease reaper backstops server-side).
        with eng.connect(c) as session:
            for _ in range(args.requests_per_client):
                prompt = np.concatenate([
                    shared,
                    rng.integers(0, cfg.vocab_size, args.prompt_len)])
                # submit_i never blocks: a full intake ring just leaves
                # the handle PENDING and its own polling retries the send.
                if overload is not None:
                    u = rng.random()
                    pri = (PRIORITY_HIGH if u < 0.2
                           else PRIORITY_NORMAL if u < 0.8 else PRIORITY_LOW)
                    handle = session.submit_i(prompt,
                                              max_tokens=args.max_tokens,
                                              priority=pri)
                else:
                    handle = session.submit_i(prompt,
                                              max_tokens=args.max_tokens)
                n_stream = sum(1 for _ in handle.tokens(timeout_s=300))
                r = handle.response
                assert r is not None and n_stream == len(r.tokens_out)
                # Rejected/cancelled requests never produced a first
                # token; report their ttft as completion time like the
                # wave baseline.
                ttft_t = r.first_token_t or r.done_t
                status = results[c].send((r.done_t - r.submit_t,
                                          ttft_t - r.submit_t))
                assert status == nbb.OK     # sized to fit every result

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(args.clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.monotonic() - t0
    eng.stop()
    eng_thread.join(timeout=10)
    for sig, h in prev_handlers.items():
        signal.signal(sig, h)
    if snapshot_dir is not None:
        print(f"crash recovery: {eng.stats['snapshots']} snapshots "
              f"({eng.stats['snapshot_bytes'] / 1024:.0f} KiB last), "
              f"{eng.stats['restores']} restores, "
              f"{eng.stats['replayed_requests']} replayed -> "
              f"{snapshot_dir}")

    lat, ttft = [], []
    for ring in results:                 # Transport-protocol drain
        for total_s, ttft_s in ring.drain():
            lat.append(total_s * 1e3)
            ttft.append(ttft_s * 1e3)
    lat.sort()
    ttft.sort()

    n = args.clients * args.requests_per_client
    toks = sum(args.max_tokens for _ in range(n))
    print(f"served {eng.stats['served']} requests in {dt:.2f}s "
          f"({n / dt:.1f} req/s, {toks / dt:.1f} tok/s)")
    print(f"latency ms: p50 {_pct(lat, 0.5):.0f} p95 {_pct(lat, 0.95):.0f}")
    print(f"ttft ms:    p50 {_pct(ttft, 0.5):.0f} p95 {_pct(ttft, 0.95):.0f}")
    print(f"engine stats: {eng.stats}")
    # Robustness report (DESIGN.md §13): what the self-healing machinery
    # actually did this run — all zeros unless a fault plan or lease was
    # armed, but printed whenever the knobs are on so the counters are
    # visible where operators look for them.
    if args.lease_s is not None or eng.faults is not None:
        fr = eng.fault_report()
        print(f"robustness: faults injected {fr['faults_injected']}  "
              f"requests failed {fr['requests_failed']}  "
              f"leases reaped {fr['leases_reaped']}  "
              f"pages quarantined {fr['pages_quarantined']}  "
              f"dead: {fr['dead'] or 'no'}")
    if scheduler != "wave":
        syncs_tok = eng.stats["host_syncs"] / max(toks, 1)
        print(f"slot occupancy: {eng.occupancy():.2f}  "
              f"host syncs/token: {syncs_tok:.2f}  "
              f"admission stall steps: "
              f"{eng.stats['admission_stall_steps']}  "
              f"oversize rejects: {len(eng.oversize_log)}  "
              f"kv pool: {eng.pool.stats()}")
    # KV-memory report (DESIGN.md §10): what residency actually cost.
    # Paged holds peak-resident page bytes and copies nothing; the dense
    # schedulers hold the full batch cache and pay admission copies.
    pstats = eng.pool.stats()
    dense_b = eng.dense_cache_bytes()
    resident = (pstats["kv_resident_bytes_peak"]
                if scheduler == "slot_paged" else dense_b)
    print(f"kv memory: resident {resident / 1024:.0f} KiB "
          f"(dense batch cache would be {dense_b / 1024:.0f} KiB, "
          f"{resident / max(dense_b, 1):.2f}x)  "
          f"kv copy traffic: {pstats['kv_copy_bytes'] / 1024:.0f} KiB")
    # Overload-control report (DESIGN.md §12): who waited, who got
    # swapped, who got shed — the honest cost of the priority tiers.
    if overload is not None:
        names = {PRIORITY_HIGH: "high", PRIORITY_NORMAL: "normal",
                 PRIORITY_LOW: "low"}
        for cls in sorted(eng.class_ttft()):
            c = eng.class_ttft()[cls]
            print(f"ttft[{names.get(cls, cls)}]: "
                  f"p50 {c['p50_ms']:.0f} p99 {c['p99_ms']:.0f} ms "
                  f"(n={c['n']})")
        print(f"overload: preemptions {eng.stats['preemptions']}  "
              f"resumes {eng.stats['resumes']}  "
              f"shed {eng.stats['shed_requests']}  "
              f"swap out {eng.stats['swap_out_bytes'] / 1024:.0f} KiB  "
              f"swap in {eng.stats['swap_in_bytes'] / 1024:.0f} KiB")
    # Prefix-sharing report (DESIGN.md §11): what the cache bought.
    if eng.prefix_cache is not None:
        cstats = eng.prefix_cache.stats()
        looked = cstats["hits"] + cstats["misses"]
        rate = cstats["hits"] / looked if looked else 0.0
        print(f"prefix cache: hit rate {rate:.2f} "
              f"({cstats['hits']}/{looked} lookups)  "
              f"prefill tokens saved {eng.stats['prefill_tokens_saved']}  "
              f"entries {cstats['entries']} "
              f"(evictions {cstats['evictions']})  "
              f"shared pages peak {pstats['shared_pages_peak']}  "
              f"cow copies {pstats['cow_copy_bytes'] / 1024:.0f} KiB")
    return eng


if __name__ == "__main__":
    main()
