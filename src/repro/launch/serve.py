"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Starts the lock-free ServeEngine and drives it with synthetic client
threads; prints throughput/latency and the engine's lock-free stats.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main(argv=None) -> ServeEngine:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--requests-per-client", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--scheduler", default="slot", choices=["slot", "wave"],
                    help="slot = iteration-level continuous batching "
                         "(default); wave = batch-level baseline")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_batch=args.max_batch,
                      max_len=args.max_len, n_clients=args.clients,
                      pool_pages=max(256, args.clients * 16),
                      scheduler=args.scheduler)
    eng_thread = eng.start()

    lat: list = []
    lock_free_note = threading.Lock()  # only guards the results list below

    def client(c: int) -> None:
        rng = np.random.default_rng(c)
        done = 0
        while done < args.requests_per_client:
            prompt = rng.integers(0, cfg.vocab_size, args.prompt_len)
            if eng.submit(c, prompt, max_tokens=args.max_tokens) is None:
                time.sleep(0.001)
                continue
            r = eng.get_response(c, timeout_s=300)
            assert r is not None
            with lock_free_note:
                lat.append(r.done_t - r.submit_t)
            done += 1

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(args.clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.monotonic() - t0
    eng.stop()
    eng_thread.join(timeout=10)

    n = args.clients * args.requests_per_client
    toks = sum(args.max_tokens for _ in range(n))
    lat_ms = sorted(x * 1e3 for x in lat)
    print(f"served {eng.stats['served']} requests in {dt:.2f}s "
          f"({n / dt:.1f} req/s, {toks / dt:.1f} tok/s)")
    print(f"latency ms: p50 {lat_ms[len(lat_ms) // 2]:.0f} "
          f"p95 {lat_ms[int(len(lat_ms) * 0.95)]:.0f}")
    print(f"engine stats: {eng.stats}")
    if args.scheduler == "slot":
        print(f"slot occupancy: {eng.occupancy():.2f}  "
              f"kv pool: {eng.pool.stats()}")
    return eng


if __name__ == "__main__":
    main()
