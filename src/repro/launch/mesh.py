"""Production mesh construction (DESIGN.md §7).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (1x1, same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
