"""Crash-consistent engine snapshots + write-ahead intake journal
(DESIGN.md §14).

Two complementary durability mechanisms:

- :class:`EngineSnapshot` — a full image of the serving engine's host
  state at a **tick boundary**: pool block tables + refcounts + the
  bytes of every written physical page (once per page, however many
  sequences share it), prefix-cache entries, every bound slot's
  Figure-4 FSM and decode cursors, parked sequences (their
  ``SwapImage`` host bytes travel along), deferred/queued requests, and
  the terminals still sitting undelivered in response rings.  Written
  with a tmp-file + blake2b-checksum + atomic-rename protocol, so a
  crash *during* snapshot write can never damage the last good
  snapshot — the loader checksum-rejects torn files and falls back.

- :class:`IntakeJournal` — an append-only WAL of BIND records.  A
  submission accepted after the last snapshot has no page/slot state
  worth imaging yet; its prompt + decode parameters are enough to
  replay it deterministically (greedy decode makes replay exact).  The
  journal is the cheap half of the division of labor: snapshots are
  periodic and heavy, journal appends are per-bind and tiny.

Fault sites (``core.faults``): ``snapshot.write`` tears the file
mid-write (simulating death during checkpoint), ``snapshot.restore``
aborts a restore before any mutation, ``journal.append`` loses one WAL
record.  All three are probed by the callers in ``serve/engine.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import struct
from typing import Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"RSNAP1\n"
_HDR = struct.Struct("<Q16s")        # payload length + blake2b-128 digest
_JHDR = struct.Struct("<I8s")        # record length + blake2b-64 digest


class SnapshotError(RuntimeError):
    """A snapshot could not be written, read, or restored."""


def _digest(payload: bytes, size: int) -> bytes:
    return hashlib.blake2b(payload, digest_size=size).digest()


@dataclasses.dataclass
class SlotImage:
    """One bound decode slot, exactly as the scheduler left it at the
    tick boundary: the Figure-4 buffer FSM cell (``fsm``), the request
    (its own Figure-3 FSM rides inside), decode cursors (``pos`` /
    ``cur_token``), the emitted-token high-water mark (``generated`` —
    every position below it has been streamed at least once), the
    output buffer, and the chunked-prefill extent (``prefill_pos`` plus
    the staged padded prompt)."""
    index: int
    fsm: object
    request: object
    cur_token: int
    pos: int
    generated: int
    outs: Optional[np.ndarray]
    prompt: Optional[np.ndarray]
    prefill_pos: int
    next_tok: Optional[int]
    chunk_hashes: List[int]
    pending_prefix: List[Tuple]
    created_prefixes: List[Tuple]
    fresh_resume: bool


@dataclasses.dataclass
class EngineSnapshot:
    """Everything ``ServeEngine.restore`` needs, host-side and
    self-contained.  ``config`` is the engine fingerprint asserted at
    restore (a snapshot only restores onto an identically-shaped
    engine); ``journal_seq`` is the WAL high-water mark — records at or
    beyond it replay as fresh submissions."""
    config: Dict[str, object]
    journal_seq: int
    next_req_id: int
    pool: Dict[str, object]
    prefix_entries: List[Tuple[int, int, List[int]]]   # LRU order
    slots: List[SlotImage]
    cur: np.ndarray
    pos: np.ndarray
    parked: List[object]
    deferred: List[Tuple[object, List[int]]]
    queued: List[object]                    # intake-resident requests
    undelivered: Dict[int, List[object]]    # client -> terminals in-ring
    stats: Dict[str, object]


# -- ring peeking ------------------------------------------------------------

def peek_ring(ring) -> List[object]:
    """Non-destructively read every committed item in a HostNBB ring in
    consumer order.  Snapshot capture must not consume: the running
    engine (and its clients) still own these entries; the snapshot just
    records what a crash at this boundary would strand in flight."""
    ring = getattr(ring, "inner", ring)     # unwrap FaultyTransport
    uc, ac, n = ring._uc, ring._ac, ring._n
    avail = (uc // 2) - (ac // 2)
    start = (ac // 2) % n
    return [ring._slots[(start + j) % n] for j in range(avail)]


# -- snapshot files ----------------------------------------------------------

def _snap_paths(dirpath: str) -> List[str]:
    try:
        names = sorted(n for n in os.listdir(dirpath)
                       if n.startswith("snap-") and n.endswith(".ckpt"))
    except FileNotFoundError:
        return []
    return [os.path.join(dirpath, n) for n in names]


def write_snapshot(snap: EngineSnapshot, dirpath: str, *,
                   faults=None, keep: int = 8) -> Optional[str]:
    """Serialize + write with the torn-write-safe protocol: full blob to
    a ``.tmp`` sibling, fsync, then atomic rename.  The ``snapshot.write``
    fault site simulates the process dying mid-write — half the blob
    lands at the FINAL name, which is exactly the corruption the loader
    must survive (checksum reject + fall back to the previous good
    file).  Returns the path on success, None on an injected tear."""
    os.makedirs(dirpath, exist_ok=True)
    payload = pickle.dumps(snap, protocol=pickle.HIGHEST_PROTOCOL)
    blob = MAGIC + _HDR.pack(len(payload), _digest(payload, 16)) + payload
    existing = _snap_paths(dirpath)
    seq = 0
    if existing:
        seq = 1 + max(int(os.path.basename(p)[5:-5]) for p in existing)
    final = os.path.join(dirpath, f"snap-{seq:08d}.ckpt")
    if faults is not None and faults.fire("snapshot.write") is not None:
        with open(final, "wb") as f:        # torn: no tmp, no rename
            f.write(blob[:max(1, len(blob) // 2)])
        return None
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    for p in _snap_paths(dirpath)[:-keep]:
        with open(p, "rb"):                 # touch before unlink: be sure
            pass                            # it's ours, not a foreign file
        os.unlink(p)
    return final


def read_snapshot(path: str) -> EngineSnapshot:
    """Read + validate one snapshot file; :class:`SnapshotError` on any
    torn/truncated/corrupt content."""
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except OSError as exc:
        raise SnapshotError(f"unreadable snapshot {path}: {exc}")
    if not blob.startswith(MAGIC) or len(blob) < len(MAGIC) + _HDR.size:
        raise SnapshotError(f"torn snapshot {path}: bad header")
    length, digest = _HDR.unpack_from(blob, len(MAGIC))
    payload = blob[len(MAGIC) + _HDR.size:]
    if len(payload) != length or _digest(payload, 16) != digest:
        raise SnapshotError(f"torn snapshot {path}: checksum mismatch")
    try:
        snap = pickle.loads(payload)
    except Exception as exc:
        raise SnapshotError(f"undecodable snapshot {path}: {exc}")
    if not isinstance(snap, EngineSnapshot):
        raise SnapshotError(f"not an EngineSnapshot: {path}")
    return snap


def load_latest(dirpath: str) -> Tuple[Optional[EngineSnapshot],
                                       Optional[str]]:
    """Newest *valid* snapshot in ``dirpath`` — torn files (from a crash
    or an injected ``snapshot.write`` fault) are skipped, falling back
    to the previous good one.  ``(None, None)`` when nothing usable."""
    for path in reversed(_snap_paths(dirpath)):
        try:
            return read_snapshot(path), path
        except SnapshotError:
            continue
    return None, None


# -- the write-ahead intake journal ------------------------------------------

class IntakeJournal:
    """Append-only BIND log with per-record checksum framing.

    Torn tails (a crash mid-append) are tolerated: on open, the file is
    scanned record-by-record and truncated back to the last good frame,
    so the next append never buries valid records behind garbage.
    ``records`` holds every surviving record in append order;
    ``seq`` (== len(records)) is the high-water mark snapshots capture.
    """

    def __init__(self, path: str):
        self.path = path
        self.records: List[Dict] = []
        good = 0
        if os.path.exists(path):
            with open(path, "rb") as f:
                blob = f.read()
            off = 0
            while off + _JHDR.size <= len(blob):
                length, digest = _JHDR.unpack_from(blob, off)
                body = blob[off + _JHDR.size: off + _JHDR.size + length]
                if len(body) != length or _digest(body, 8) != digest:
                    break
                try:
                    self.records.append(pickle.loads(body))
                except Exception:
                    break
                off += _JHDR.size + length
                good = off
            if good != len(blob):
                with open(path, "r+b") as f:   # drop the torn tail
                    f.truncate(good)
        else:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.seq = len(self.records)
        self._f = open(path, "ab")

    def append(self, record: Dict) -> int:
        """Durably append one record; returns its sequence number."""
        body = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
        self._f.write(_JHDR.pack(len(body), _digest(body, 8)) + body)
        self._f.flush()
        os.fsync(self._f.fileno())
        self.records.append(record)
        seq = self.seq
        self.seq += 1
        return seq

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass
