"""Serving engine: lock-free request intake, iteration-level batching.

MCAPI topology, lock-free end to end (paper Figures 1-4 without the red
lock):

  client threads --SPSC NBB rings--> slot batcher --> prefill+decode -->
      --per-client SPSC response rings--> clients

  * intake      — each client owns a private SPSC ring of an MpscQueue;
                  submission is a Transport ``send`` with Table-1 status
                  codes; the batcher drains via the same protocol.
  * lifecycle   — every request carries a CAS FSM cell (Figure 3):
                  FREE->VALID on submit, ->RECEIVED when batched,
                  ->COMPLETED on finish, ->CANCELLED on reject;
                  illegal transitions throw, catching scheduler bugs.
  * KV memory   — admission claims pages from the lock-free bitset pool
                  (kv_cache.py); a full pool *rejects* (BUFFER_FULL
                  semantics) instead of blocking the batcher.
  * decode      — ITERATION-LEVEL continuous batching (the default): a
                  fixed pool of ``max_batch`` decode slots, each driven
                  by the paper's Figure-4 buffer FSM
                  (FREE->RESERVED->ALLOCATED->RECEIVED->FREE).  A slot is
                  RESERVED when its KV pages are claimed, ALLOCATED once
                  the prompt is prefilled into its rows of the persistent
                  batch cache, RECEIVED when the finished sequence is
                  handed back, then FREE again — all at the granularity
                  of a *single decode step*, so finished sequences
                  release their slot and pages immediately and waiting
                  requests swap in without stopping decode.  No global
                  wave barrier: the serving-layer analogue of deleting
                  the queue lock (DESIGN.md §4).
                  ``scheduler="wave"`` keeps the old batch-level wave
                  scheduler as the convoying baseline for A/B
                  benchmarking (benchmarks/bench_serve.py).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nbb, states, transport
from repro.core.host_queue import MpscQueue, SpscQueue
from repro.serve.kv_cache import OK as POOL_OK
from repro.serve.kv_cache import PagedKVPool


@dataclasses.dataclass
class Request:
    req_id: int
    client_id: int
    prompt: np.ndarray                  # [T] int32
    max_tokens: int = 16
    eos_id: int = -1                    # -1: never
    fsm: states.StateCell = dataclasses.field(
        default_factory=lambda: states.request_cell())
    tokens_out: Optional[np.ndarray] = None
    submit_t: float = 0.0
    done_t: float = 0.0


@dataclasses.dataclass
class DecodeSlot:
    """One row of the persistent batch cache, owned by at most one
    sequence at a time.  ``fsm`` is the paper's Figure-4 buffer cell —
    every occupancy change is a CAS transition, so a scheduler bug that
    double-books or early-frees a slot raises instead of corrupting KV."""

    index: int
    fsm: states.StateCell = dataclasses.field(
        default_factory=lambda: states.buffer_cell())
    request: Optional[Request] = None
    next_tok: int = 0                   # token produced, not yet harvested
    pos: int = 0                        # tokens written to this row's cache
    generated: int = 0
    outs: Optional[np.ndarray] = None


def _write_slot_caches(full, one, slot):
    """Copy a B=1 prefilled cache into row ``slot`` of the batch cache.

    The batch axis of each leaf is located structurally: it is the single
    axis where the full cache is wider than the single-sequence cache
    (works for every cache family — attention rings, mamba/rwkv state,
    nested superblocks — without per-family code)."""
    def put(f, o):
        if f.shape == o.shape:          # max_batch == 1
            return o
        diff = [i for i in range(f.ndim) if f.shape[i] != o.shape[i]]
        assert len(diff) == 1 and o.shape[diff[0]] == 1, (f.shape, o.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=diff[0])
    return jax.tree.map(put, full, one)


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 128, n_clients: int = 2,
                 pool_pages: int = 64, page_size: int = 16,
                 intake_depth: int = 32, scheduler: str = "slot"):
        if scheduler not in ("slot", "wave"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.model, self.params = model, params
        self.max_batch, self.max_len = max_batch, max_len
        self.scheduler = scheduler
        cfg = model.cfg
        self.intake = MpscQueue(n_clients, capacity_per_producer=intake_depth)
        self.responses = [SpscQueue(intake_depth) for _ in range(n_clients)]
        self.pool = PagedKVPool(
            pool_pages, page_size, n_layers=cfg.num_layers,
            kv_heads=max(cfg.num_kv_heads, 1), head_dim=cfg.head_dim_ or 1,
            dtype=cfg.compute_dtype)
        self._id = itertools.count()
        self._stop = threading.Event()
        self._jit_decode = jax.jit(model.decode_step)
        self._jit_write_slot = jax.jit(_write_slot_caches)
        # One jitted prefill; jax specializes it per (batch, prompt) shape.
        self._jit_prefill = jax.jit(
            lambda p, t: model.prefill(p, t, self.max_len))
        # Slot state (iteration-level scheduler).
        self.slots = [DecodeSlot(i) for i in range(max_batch)]
        self._caches = None             # persistent [max_batch, ...] cache
        self._cur = np.zeros((max_batch,), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self.stats = {"served": 0, "rejected": 0, "batches": 0,
                      "decode_steps": 0, "admitted": 0, "prefills": 0,
                      "slot_busy_steps": 0, "dropped_responses": 0}

    # -- client API (any thread) ------------------------------------------------
    def submit(self, client_id: int, prompt: np.ndarray,
               max_tokens: int = 16, eos_id: int = -1) -> Optional[Request]:
        """Non-blocking submit.  None => intake ring full (caller retries)."""
        req = Request(next(self._id), client_id, np.asarray(prompt, np.int32),
                      max_tokens, eos_id, submit_t=time.monotonic())
        req.fsm.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        status = self.intake.producer(client_id).send(req)
        if status != nbb.OK:
            req.fsm.transition(states.REQUEST_VALID, states.REQUEST_CANCELLED)
            return None
        return req

    # -- shared helpers -----------------------------------------------------------
    def _respond(self, req: Request) -> None:
        # Response ring full => bounded backoff, never a spin-pin.  The
        # send can only fail during shutdown (should_stop); record the
        # drop so stats never silently overcount deliveries.
        if not transport.send_blocking(self.responses[req.client_id], req,
                                       should_stop=self._stop.is_set):
            self.stats["dropped_responses"] += 1

    def _reject(self, req: Request) -> None:
        req.fsm.transition(states.REQUEST_VALID, states.REQUEST_CANCELLED)
        req.done_t = time.monotonic()
        self.stats["rejected"] += 1
        self._respond(req)

    # ===========================================================================
    # Iteration-level scheduler (default): slot swap, no wave barrier.
    # ===========================================================================
    def _bucket(self, n: int) -> int:
        """Pad prompts to power-of-two buckets (>=8) to bound the number
        of prefill traces; left-padding matches the wave scheduler."""
        b = 8
        while b < n:
            b *= 2
        return b

    def _ensure_caches(self) -> None:
        if self._caches is None:
            self._caches = self.model.init_cache(self.max_batch, self.max_len)

    def _admit_into(self, slot: DecodeSlot) -> bool:
        """Swap one waiting request into a FREE slot.  Returns False when
        the intake fan-in is empty; pool-full requests are rejected (the
        NBB BUFFER_FULL discipline), never queued behind a blocked slot."""
        while True:
            status, req = self.intake.try_recv()
            if status != nbb.OK:
                return False
            padded = self._bucket(len(req.prompt))
            need = padded + req.max_tokens
            if padded + req.max_tokens > self.max_len or self.pool.try_admit(
                    req.req_id, need, slot=slot.index) != POOL_OK:
                self._reject(req)
                continue
            break
        if not any(s.request is not None for s in self.slots):
            self.stats["batches"] += 1      # new busy period begins
        # Figure-4 lifecycle: FREE -> RESERVED (pages claimed) ...
        slot.fsm.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        prompt = np.zeros((padded,), np.int32)
        prompt[padded - len(req.prompt):] = req.prompt      # left-pad
        tok, one_cache = self._jit_prefill(self.params,
                                           jnp.asarray(prompt[None]))
        self.stats["prefills"] += 1
        self._ensure_caches()
        self._caches = self._jit_write_slot(self._caches, one_cache,
                                            jnp.int32(slot.index))
        # ... -> ALLOCATED (KV materialized in this slot's cache rows).
        slot.fsm.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        req.fsm.transition(states.REQUEST_VALID, states.REQUEST_RECEIVED)
        slot.request = req
        slot.next_tok = int(np.asarray(tok)[0])
        slot.pos = padded
        slot.generated = 0
        slot.outs = np.full((req.max_tokens,), -1, np.int64)
        self._pos[slot.index] = padded
        self._cur[slot.index] = slot.next_tok
        self.stats["admitted"] += 1
        return True

    def _retire(self, slot: DecodeSlot) -> None:
        """End-of-step release: slot + KV pages return to the pool the
        moment a sequence finishes — the next tick can swap a waiting
        request in while the other slots keep decoding."""
        req = slot.request
        req.tokens_out = slot.outs[:slot.generated].astype(np.int32)
        req.done_t = time.monotonic()
        req.fsm.transition(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED)
        self.pool.free(req.req_id)
        self.stats["served"] += 1
        self._respond(req)
        # ALLOCATED -> RECEIVED (handed to consumer) -> FREE.
        slot.fsm.transition(states.BUFFER_ALLOCATED, states.BUFFER_RECEIVED)
        slot.fsm.transition(states.BUFFER_RECEIVED, states.BUFFER_FREE)
        slot.request = None
        slot.outs = None
        self._cur[slot.index] = 0
        self._pos[slot.index] = 0

    def tick(self) -> Tuple[int, bool]:
        """One engine iteration: swap in, harvest+retire, one decode step
        for the whole slot pool.  Returns (requests served, did work)."""
        served, worked = 0, False
        # 1) Swap waiting requests into FREE slots (lock-free intake).
        for slot in self.slots:
            if slot.request is None:
                if not self._admit_into(slot):
                    break
                worked = True
        # 2) Harvest the token each active slot produced (prefill or the
        #    previous decode step); retire finished sequences NOW.
        for slot in self.slots:
            req = slot.request
            if req is None:
                continue
            slot.outs[slot.generated] = slot.next_tok
            slot.generated += 1
            worked = True
            if (slot.next_tok == req.eos_id
                    or slot.generated >= req.max_tokens
                    or slot.pos + 1 >= self.max_len):
                self._retire(slot)
                served += 1
        # 3) One decode step over the fixed-shape batch; idle rows are
        #    masked by their own per-row position (layers.attention).
        active = [s for s in self.slots if s.request is not None]
        if active:
            cur, self._caches = self._jit_decode(
                self.params, self._caches, jnp.asarray(self._cur)[:, None],
                jnp.asarray(self._pos))
            cur = np.asarray(cur)
            for s in active:
                s.next_tok = int(cur[s.index])
                s.pos += 1
                self._pos[s.index] = s.pos
                self._cur[s.index] = s.next_tok
                self.pool.note_tokens(s.request.req_id, s.pos)
            self.stats["decode_steps"] += 1
            self.stats["slot_busy_steps"] += len(active)
            worked = True
        return served, worked

    def occupancy(self) -> float:
        """Mean fraction of decode slots doing useful work per step."""
        steps = self.stats["decode_steps"]
        return (self.stats["slot_busy_steps"] / (steps * self.max_batch)
                if steps else 0.0)

    # ===========================================================================
    # Wave scheduler (baseline): batch-level waves, kept for A/B benchmarks.
    # ===========================================================================
    def _take_batch(self, timeout_s: float = 0.05) -> List[Request]:
        """Greedy batcher: first request blocks briefly, rest drained free."""
        batch: List[Request] = []
        deadline = time.monotonic() + timeout_s
        backoff = transport.Backoff()
        while len(batch) < self.max_batch:
            status, req = self.intake.try_recv()
            if status == nbb.OK:
                backoff.reset()
                # admission control: KV pages for prompt + generation
                need = len(req.prompt) + req.max_tokens
                if self.pool.try_admit(req.req_id, need) != POOL_OK:
                    self._reject(req)
                    continue
                req.fsm.transition(states.REQUEST_VALID,
                                   states.REQUEST_RECEIVED)
                batch.append(req)
            elif batch or time.monotonic() > deadline:
                break
            else:
                # Table-1 discipline: spin on transient, then yield, then
                # exponential sleep — not a fixed 1 ms busy-wait.
                backoff.wait(status)
        return batch

    def _run_batch(self, batch: List[Request]) -> None:
        B = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        tok, caches = self._jit_prefill(self.params, jnp.asarray(toks))
        self.stats["prefills"] += 1

        max_new = max(r.max_tokens for r in batch)
        outs = np.full((B, max_new), -1, np.int64)
        done = np.zeros((B,), bool)
        cur = tok
        for step in range(max_new):
            outs[~done, step] = np.asarray(cur)[~done]
            for i, r in enumerate(batch):
                if not done[i] and (outs[i, step] == r.eos_id
                                    or step + 1 >= r.max_tokens):
                    done[i] = True
            if done.all() or plen + step + 1 >= self.max_len:
                break
            cur, caches = self._jit_decode(self.params, caches, cur[:, None],
                                           jnp.int32(plen + step))
            self.stats["decode_steps"] += 1

        for i, r in enumerate(batch):
            got = outs[i][outs[i] >= 0].astype(np.int32)
            r.tokens_out = got
            r.done_t = time.monotonic()
            r.fsm.transition(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED)
            self.pool.free(r.req_id)
            self.stats["served"] += 1
            self._respond(r)
        self.stats["batches"] += 1

    # -- engine loop --------------------------------------------------------------
    def step(self) -> int:
        """Drain everything currently runnable; returns requests served.

        Wave scheduler: one fused batch.  Slot scheduler: tick until the
        slot pool and intake are both idle (each tick is one decode
        step, so admissions interleave with decode)."""
        if self.scheduler == "wave":
            batch = self._take_batch()
            if not batch:
                return 0
            self._run_batch(batch)
            return len(batch)
        total = 0
        while True:
            served, worked = self.tick()
            total += served
            if not worked:
                return total

    def serve_forever(self) -> None:
        backoff = transport.Backoff()
        while not self._stop.is_set():
            if self.scheduler == "wave":
                worked = self.step() > 0
            else:
                _, worked = self.tick()
            if worked:
                backoff.reset()
            else:
                backoff.wait(nbb.BUFFER_EMPTY)

    def start(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def stop(self) -> None:
        self._stop.set()

    # -- client-side receive -----------------------------------------------------
    def get_response(self, client_id: int, timeout_s: float = 30.0
                     ) -> Optional[Request]:
        status, req = transport.recv_blocking(self.responses[client_id],
                                              timeout_s=timeout_s)
        return req if status == nbb.OK else None
