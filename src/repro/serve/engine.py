"""Serving engine: lock-free request intake, iteration-level batching.

MCAPI topology, lock-free end to end (paper Figures 1-4 without the red
lock):

  client threads --SPSC NBB rings--> slot batcher --> prefill+decode -->
      --per-client SPSC response rings--> clients

  * intake      — each client owns a private SPSC ring of an MpscQueue;
                  submission is a Transport ``send`` with Table-1 status
                  codes; the batcher drains via the same protocol.
  * lifecycle   — every request carries a CAS FSM cell (Figure 3):
                  FREE->VALID on submit, ->RECEIVED when batched,
                  ->COMPLETED on finish, ->CANCELLED on reject;
                  illegal transitions throw, catching scheduler bugs.
  * KV memory   — admission claims pages from the lock-free bitset pool
                  (kv_cache.py); a full pool *rejects* (BUFFER_FULL
                  semantics) instead of blocking the batcher.
  * decode      — ITERATION-LEVEL continuous batching: a fixed pool of
                  ``max_batch`` decode slots, each driven by the paper's
                  Figure-4 buffer FSM
                  (FREE->RESERVED->ALLOCATED->RECEIVED->FREE).  A slot is
                  RESERVED when its KV pages are claimed, ALLOCATED once
                  the prompt is prefilled into its rows of the persistent
                  batch cache, RECEIVED when the finished sequence is
                  handed back, then FREE again — finished sequences
                  release their slot and pages at block granularity and
                  waiting requests swap in without stopping decode.  No
                  global wave barrier: the serving-layer analogue of
                  deleting the queue lock (DESIGN.md §4).
  * packet mode — the default scheduler (``"slot_fused"``) runs decode
                  in FUSED BLOCKS of K steps (``Model.decode_loop``, a
                  lax.scan on device): one jitted dispatch, one
                  device->host sync, one page-accounting call and one
                  stream-ring burst per block instead of per token — the
                  paper's scalar-vs-packet exchange amortization
                  (Tables 5-7) applied to the decode loop (DESIGN.md
                  §6).  K adapts per block: capped by the smallest
                  remaining token budget (blocks end exactly when the
                  first sequence finishes) and by ``k_free`` while a
                  slot is FREE (bounded admission latency for arrivals).
                  ``scheduler="slot"`` keeps the per-token scalar path
                  and ``scheduler="wave"`` the batch-level wave
                  scheduler as baselines for A/B benchmarking
                  (benchmarks/bench_serve.py).
  * admission   — ``scheduler="slot_chunked"`` extends the fused path
                  with CHUNKED ZERO-COPY ADMISSION (DESIGN.md §9): a
                  RESERVED slot streams its prompt ``chunk_tokens`` at a
                  time *in the same jitted dispatch* that advances the
                  active rows K decode steps
                  (``Model.chunked_block``, Sarathi-style
                  piggybacking).  Each chunk's KV is written in place
                  into the slot's rows of the persistent batch cache —
                  no B=1 side cache, no copy-into-slot dispatch, no
                  per-admission host sync (the prefill's first token
                  rides the regular block fetch) — and KV pages are
                  claimed chunk by chunk as positions materialize.  A
                  long prompt therefore never stalls active decode:
                  every one of its dispatches also carries a decode
                  block (``stats["admission_stall_steps"]`` stays 0,
                  where the monolithic prefill stalls every active slot
                  once per admission).
  * residency   — ``scheduler="slot_paged"`` keeps the chunked
                  scheduler's whole dispatch discipline (chunked
                  admission riding the fused K-step decode block, ONE
                  dispatch / ONE sync per tick) but deletes the dense
                  per-slot batch cache: the page pool's ``k``/``v``
                  arrays are THE device-resident KV store and each slot
                  holds only an int32 block-table row + a length
                  (DESIGN.md §10, the vLLM idea as the KV-domain
                  Virtual-Link analogue).  Decode attends straight
                  through the block table (expressed in jnp inside the
                  jitted dispatch; ``kernels/paged_attention.py`` is
                  the validated Pallas lowering of the same access
                  pattern for a TPU deployment), new K/V scatters to
                  (page, offset) computed on device, and
                  admission/retire/"swap" reduce to editing
                  int32 rows and bitset pages — zero KV gather/scatter
                  dispatches at steady state (``kv_copy_bytes == 0``)
                  and per-slot memory proportional to actual tokens,
                  not ``max_len``, so ``max_batch`` can rise on the
                  same HBM budget.
  * streaming   — the client surface is handle-based and per-token
                  (DESIGN.md §5): ``engine.connect(client_id)`` returns
                  the client's :class:`Session`;
                  ``session.submit_i(...)`` returns a
                  :class:`RequestHandle` whose ``tokens()`` iterator
                  yields ``(pos, token)`` pairs as the batcher harvests
                  them — packed int64 scalars delivered in per-block
                  BURSTS on the client's SPSC stream ring and drained in
                  bursts per wakeup — and whose ``cancel()``
                  CASes the request FSM so the batcher retires the slot
                  and frees its KV pages *mid-decode*.  The legacy
                  blocking calls (``submit``/``get_response``) are thin
                  wrappers over session + handle.
  * overload    — an optional :class:`~repro.serve.overload.
                  OverloadPolicy` (DESIGN.md §12) turns the intake into
                  the multi-class weighted-fair fan-in
                  (``submit_i(priority=...)``, strict priority with
                  aging + per-client WFQ over the same lock-free SPSC
                  rings), sheds queued requests past their TTFT SLO
                  with a typed falsy ``ShedStatus``, and — under
                  ``slot_paged`` — PREEMPTS lower-priority decoding
                  sequences when urgent work needs their slot or pages:
                  private KV pages swap host-side (shared prefix pages
                  stay resident), the Figure-4 cell parks in
                  BUFFER_PREEMPTED, and the sequence later resumes
                  byte-identically through the block-table indirection.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
import os
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import nbb, states, transport
from repro.core.host_queue import MpscQueue, SpscQueue
from repro.models.model import prefix_chunk_hashes
from repro.serve import snapshot as snapshot_mod
from repro.serve.kv_cache import OK as POOL_OK
from repro.serve.kv_cache import (PagedKVPool, PrefixCache, PrefixEntry,
                                  SwapImage)
from repro.serve.snapshot import SnapshotError
from repro.serve.overload import (OverloadPolicy, PriorityIntake,
                                  ShedStatus)


@dataclasses.dataclass
class Request:
    req_id: int
    client_id: int
    prompt: np.ndarray                  # [T] int32
    max_tokens: int = 16
    eos_id: int = -1                    # -1: never
    fsm: states.StateCell = dataclasses.field(
        default_factory=lambda: states.request_cell())
    tokens_out: Optional[np.ndarray] = None
    submit_t: float = 0.0
    first_token_t: float = 0.0          # harvest time of token 0 (TTFT)
    done_t: float = 0.0
    token_ts: List[float] = dataclasses.field(default_factory=list)
    # Overload control (DESIGN.md §12).  ``priority`` is the submitted
    # class (0 = most urgent); ``eff_priority`` is what scheduling
    # decisions read — it starts equal and is boosted to 0 when aging
    # promotes the request, so a promotion also confers preemption
    # immunity.  ``slo_s`` is a per-request TTFT deadline overriding the
    # policy default; ``status`` carries a typed terminal status
    # (ShedStatus) back to the client handle.
    priority: int = 1
    eff_priority: int = 1
    slo_s: Optional[float] = None
    status: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class TimeoutStatus:
    """Typed timeout from the client receive surface.  Falsy, so callers
    can write ``if not resp:`` without isinstance checks, and carries the
    last Table-1 status observed instead of a bare exception."""

    waited_s: float
    status: int = nbb.BUFFER_EMPTY

    def __bool__(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class OversizeStatus:
    """Typed fail-fast rejection from :meth:`Session.submit_i`: the
    request's KV footprint (bucketed prompt + generation budget) can
    never fit the engine's cache, so it is refused at the session layer
    without an intake round-trip — the batcher never sees it.  Falsy,
    like :class:`TimeoutStatus`."""

    prompt_len: int
    padded_len: int
    max_tokens: int
    max_len: int

    def __bool__(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class FailedStatus:
    """Typed terminal failure (DESIGN.md §13): the engine — not the
    client — ended this request, because a fault landed on its slot
    (watchdog fail-all, poisoned write), its lease expired, or the
    engine died.  Falsy like :class:`TimeoutStatus`, with the
    human-readable ``reason`` attached; rides ``Request.status`` to the
    client handle, and is also what ``wait``/``get_response`` return
    when the whole engine is dead — nothing hangs on a dead engine."""

    reason: str

    def __bool__(self) -> bool:
        return False


# ---------------------------------------------------------------------------
# Streaming wire format: one packed int64 scalar per harvested token on the
# client's SPSC stream ring (the MCAPI scalar channel format), terminal
# Request objects on the response ring.  req_id rides in the top 16 bits —
# enough to demultiplex any realistic number of in-flight requests per
# client; ``pos`` is the output index, so clients reassemble by position.
# ---------------------------------------------------------------------------
_REQ_MASK = 0xFFFF


def pack_token_event(req_id: int, pos: int, token: int) -> int:
    return (((req_id & _REQ_MASK) << 48) | ((pos & 0xFFFF) << 32)
            | (token & 0xFFFFFFFF))


def unpack_token_event(ev: int) -> Tuple[int, int, int]:
    """-> (req_id mod 2^16, output position, token id)."""
    return (ev >> 48) & _REQ_MASK, (ev >> 32) & 0xFFFF, ev & 0xFFFFFFFF


class RequestHandle:
    """One in-flight request (the serving analogue of an ``OpHandle``).

    Returned by :meth:`Session.submit_i`.  The submission itself is a
    non-blocking operation handle over the client's private intake ring;
    ``test``/``wait``/``tokens`` poll it through, so a full intake ring
    delays — never blocks — the caller.  Thread contract: ``test``,
    ``wait`` and ``tokens`` belong to the owning client thread (they run
    the session's ring consumer); ``cancel`` may race from any thread.
    """

    def __init__(self, session: "Session", req: Request,
                 submit: Optional[transport.OpHandle]):
        self.req = req
        self._session = session
        self._submit = submit              # None: rejected at submit time
        self._tokens: deque = deque()      # (pos, token) routed by pump
        self._final: Optional[Request] = None
        # Typed falsy status (OversizeStatus when the session layer
        # refused the request without an intake round-trip, ShedStatus
        # when admission shed it past its SLO); None for every request
        # the engine actually served.
        self.status: Optional[object] = None

    @property
    def req_id(self) -> int:
        return self.req.req_id

    @property
    def submitted(self) -> bool:
        """The request has entered the engine's intake ring."""
        return self._submit is not None and self._submit.completed

    @property
    def done(self) -> bool:
        return self._final is not None

    @property
    def response(self) -> Optional[Request]:
        """The terminal Request (COMPLETED or CANCELLED), once delivered."""
        return self._final

    def _poll(self) -> bool:
        """One non-blocking progress attempt; True if anything moved.
        Owner-thread only — this is also where a cancelled-before-send
        request is finalized locally: the owner thread set (or didn't
        set) ``attempted_ok`` itself, so unlike ``cancel()`` it can
        trust the flag without racing an in-flight attempt."""
        if self._submit is None:            # fail-fast reject: terminal
            return False                    # was produced at submit time
        moved = False
        if not self._submit.done:
            moved = self._submit.test() or moved
        if (self._final is None and self._submit.cancelled
                and not self._submit.attempted_ok):
            # The payload never reached the intake ring; the engine will
            # never answer, so the terminal is produced here.
            self.req.done_t = time.monotonic()
            if self.req.tokens_out is None:
                self.req.tokens_out = np.zeros((0,), np.int32)
            self._session.forget(self.req.req_id)
            self._session._finalized.add(self.req.req_id)
            self._final = self.req
            return True
        moved = self._session.pump() or moved
        if self._final is None and self._session.engine.dead is not None:
            # The engine died after accepting this request: nothing will
            # ever deliver its terminal, so finalize locally with the
            # typed falsy FailedStatus instead of hanging until timeout.
            req = self.req
            if req.done_t == 0.0:
                req.done_t = time.monotonic()
            if req.tokens_out is None:
                req.tokens_out = np.zeros((0,), np.int32)
            if self.status is None:
                self.status = (req.status if req.status is not None else
                               FailedStatus(self._session.engine.dead))
            if req.status is None:
                req.status = self.status
            self._session.forget(req.req_id)
            self._session._finalized.add(req.req_id)
            self._final = req
            return True
        return moved

    def test(self) -> bool:
        """Non-blocking: True iff the request has reached a terminal
        state (its final Request is available)."""
        if self._final is None:
            self._poll()
        return self._final is not None

    def wait(self, timeout_s: Optional[float] = None
             ) -> Union[Request, TimeoutStatus, "FailedStatus"]:
        """Block (Backoff discipline) until terminal; the final Request,
        or a falsy TimeoutStatus with the handle still live.  On a dead
        engine this returns the falsy :class:`FailedStatus` immediately
        (reason attached) instead of hanging until timeout."""
        b = transport.Backoff()
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        while self._final is None:
            if self._poll():
                b.reset()
                continue
            if deadline is not None and time.monotonic() > deadline:
                return TimeoutStatus(waited_s=timeout_s)
            b.wait(nbb.BUFFER_EMPTY)
        if (isinstance(self.status, FailedStatus)
                and self._session.engine.dead is not None):
            return self.status
        return self._final

    def tokens(self, timeout_s: Optional[float] = None
               ) -> Iterator[Tuple[int, int]]:
        """Yield ``(pos, token)`` as the batcher produces them.

        Tokens stream over the client's SPSC ring (one scalar per decode
        step); when backpressure dropped an event mid-stream, the missing
        positions are filled in from the terminal ``tokens_out`` — every
        position is delivered exactly once, in nondecreasing order except
        for those recovered gaps.  ``timeout_s`` is an *idle* timeout:
        raises TimeoutError only after that long with no progress at all
        (a slow but advancing generation never trips it)."""
        b = transport.Backoff()
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        delivered = set()
        while True:
            while self._tokens:
                pos, tok = self._tokens.popleft()
                if pos not in delivered:
                    delivered.add(pos)
                    yield pos, tok
            if self._final is not None:
                out = self._final.tokens_out
                for p in range(0 if out is None else len(out)):
                    if p not in delivered:
                        yield p, int(out[p])
                return
            if self._poll():
                b.reset()
                if deadline is not None:        # progress: push it out
                    deadline = time.monotonic() + timeout_s
                continue
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"token stream idle for {timeout_s}s "
                    f"(request {self.req.req_id} not terminal)")
            b.wait(nbb.BUFFER_EMPTY)

    def cancel(self) -> bool:
        """Cancel from any thread.  Pure CAS proposals — no queue or
        registry mutation here, so it cannot race the owner thread's
        polling.  Exactly one of cancel()/completion wins the request
        FSM: on a mid-decode win the batcher retires the slot and frees
        its KV pages on its next tick, on an intake-pop win the batcher
        answers with the empty cancelled terminal, and a request whose
        submission never landed is finalized by the owner thread's next
        poll (see ``_poll``).  True iff this caller's proposal won
        somewhere along the pipeline."""
        sub_won = self._submit.cancel() if self._submit is not None else False
        fsm_won = (self.req.fsm.cas(states.REQUEST_VALID,
                                    states.REQUEST_CANCELLED)
                   or self.req.fsm.cas(states.REQUEST_RECEIVED,
                                       states.REQUEST_CANCELLED))
        return sub_won or fsm_won


class Session:
    """A client's streaming connection to the engine (``connect``).

    Owns the consumer side of the client's two SPSC rings: the *stream*
    ring (packed per-token scalars, best-effort) and the *response* ring
    (terminal Request objects, reliable).  ``pump`` demultiplexes both to
    the live handles by req_id; terminals without a live handle (legacy
    ``submit``, which detaches its handle) queue for ``next_response``.
    One session per client, created eagerly by the engine — the
    single-consumer invariant of the rings maps onto the one-client-one-
    thread contract.
    """

    def __init__(self, engine: "ServeEngine", client_id: int):
        self.engine = engine
        self.client_id = client_id
        # Terminals carry the full Request and route exactly by req_id;
        # the 16-bit wire id only routes the lossy token stream, where a
        # (vanishingly rare) mod-2^16 collision costs streamed tokens —
        # recovered from tokens_out at the terminal — never correctness.
        self._handles: Dict[int, RequestHandle] = {}    # full req_id
        self._by_mask: Dict[int, RequestHandle] = {}    # req_id & _REQ_MASK
        self._completed: deque = deque()
        # Terminal dedupe (DESIGN.md §14): a restore re-delivers the
        # terminals that were sitting undelivered in the response ring
        # at snapshot time, so a client that DID receive one before the
        # crash may see it again — the first delivery wins, duplicates
        # are dropped here.
        self._finalized: set = set()
        # Explicit teardown (DESIGN.md §13): closed sessions refuse new
        # submits with an already-terminal FailedStatus handle.
        self.closed = False
        # Lease heartbeat: any receive-side activity (pump) or a fresh
        # submit renews the client's lease; the engine's reaper treats a
        # client silent past ``lease_s`` as dead and reclaims its stake.
        self.last_pump_t = time.monotonic()

    def submit_i(self, prompt: np.ndarray, max_tokens: int = 16,
                 eos_id: int = -1, priority: Optional[int] = None,
                 slo_s: Optional[float] = None) -> RequestHandle:
        """Non-blocking submit: always returns a handle.  If the intake
        ring is full the submission stays PENDING and is retried by the
        handle's own polling (``test``/``wait``/``tokens``).

        ``priority`` is the request's class (0 = most urgent; None =
        PRIORITY_NORMAL) — honored when the engine runs an
        :class:`~repro.serve.overload.OverloadPolicy`, where it selects
        the client's per-class intake ring (still a private SPSC ring,
        so the submit path stays lock-free); ignored otherwise.
        ``slo_s`` is a per-request TTFT deadline: the batcher sheds the
        request (falsy :class:`ShedStatus` in ``handle.status``) if it
        is still queued past the deadline.

        A request whose KV footprint can never fit the engine's cache
        (``padded prompt + max_tokens > max_len``) fails FAST, here at
        the session layer: the returned handle is already terminal
        (state CANCELLED, empty output) and carries a typed
        :class:`OversizeStatus` in ``handle.status`` — no intake
        round-trip, no batcher work, no pages touched."""
        eng = self.engine
        req = Request(next(eng._id), self.client_id,
                      np.asarray(prompt, np.int32), max_tokens, eos_id,
                      submit_t=time.monotonic())
        if self.closed:
            req.fsm.transition(states.REQUEST_FREE, states.REQUEST_VALID)
            req.fsm.transition(states.REQUEST_VALID,
                               states.REQUEST_CANCELLED)
            req.done_t = time.monotonic()
            req.tokens_out = np.zeros((0,), np.int32)
            h = RequestHandle(self, req, None)
            h._final = req
            h.status = req.status = FailedStatus("session closed")
            return h
        self.last_pump_t = time.monotonic()   # submitting client is alive
        if priority is not None:
            req.priority = req.eff_priority = int(priority)
        req.slo_s = slo_s
        req.fsm.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        padded = eng._footprint(len(req.prompt))
        if padded + max_tokens > eng.max_len:
            req.fsm.transition(states.REQUEST_VALID,
                               states.REQUEST_CANCELLED)
            req.done_t = time.monotonic()
            req.tokens_out = np.zeros((0,), np.int32)
            # Append-only log (the lock-free counter idiom): client
            # threads record fail-fast rejects without a read-modify-
            # write race against the batcher's stats dict.
            eng.oversize_log.append(req.req_id)
            h = RequestHandle(self, req, None)
            h._final = req
            h.status = OversizeStatus(len(req.prompt), padded, max_tokens,
                                      eng.max_len)
            return h
        if eng._ov is not None:
            req.priority = req.eff_priority = eng.intake.clamp(req.priority)
            ring = eng.intake.producer(self.client_id, req.priority)
        else:
            ring = eng.intake.producer(self.client_id)
        h = RequestHandle(self, req, transport.send_i(ring, req))
        self._handles[req.req_id] = h
        m = req.req_id & _REQ_MASK
        if m in self._by_mask:
            # Wire-id collision with a live handle: the 16-bit stream id
            # cannot distinguish the two, so disable stream routing for
            # both rather than risk misdelivering a token — each still
            # receives every token at its terminal.
            self._by_mask.pop(m)
        else:
            self._by_mask[m] = h
        return h

    def forget(self, req_id: int) -> Optional[RequestHandle]:
        """Detach a handle: its terminal Request is routed to the
        ``next_response`` queue instead (the legacy surface)."""
        h = self._handles.pop(req_id, None)
        if h is not None and self._by_mask.get(req_id & _REQ_MASK) is h:
            self._by_mask.pop(req_id & _REQ_MASK, None)
        return h

    def pump(self) -> bool:
        """Drain both rings once, non-blocking; route events to handles.
        Both drains are packet-mode bursts: one counter announce/commit
        pair takes every queued event per wakeup, so a client that slept
        through a whole token block pays one ring exchange to catch up,
        not one round trip per token.  Returns True iff anything
        arrived."""
        self.last_pump_t = time.monotonic()     # lease heartbeat
        moved = False
        for ev in self.engine.streams[self.client_id].drain_burst():
            moved = True
            rid, pos, tok = unpack_token_event(ev)
            h = self._by_mask.get(rid)
            if h is not None:
                h._tokens.append((pos, tok))
        for req in self.engine.responses[self.client_id].drain_burst():
            moved = True
            if req.req_id in self._finalized:
                continue    # duplicate terminal re-delivered across a
                            # restart: exactly-once, first delivery won
            self._finalized.add(req.req_id)
            h = self.forget(req.req_id)
            if h is not None:
                if req.status is not None and h.status is None:
                    h.status = req.status   # e.g. ShedStatus from admission
                h._final = req
            else:
                self._completed.append(req)
        return moved

    def next_response(self, timeout_s: float = 30.0
                      ) -> Union[Request, TimeoutStatus, FailedStatus]:
        """Next terminal Request in completion order (whole-response
        surface).  Falsy TimeoutStatus on timeout — never a bare raise.
        On a dead engine, once the rings are drained, a falsy
        :class:`FailedStatus` is returned immediately (the engine will
        never produce another terminal — waiting out the timeout would
        just be a slower way to learn the same thing)."""
        b = transport.Backoff()
        deadline = time.monotonic() + timeout_s
        while True:
            if self._completed:
                return self._completed.popleft()
            if self.pump():
                b.reset()
                continue
            if self.engine.dead is not None:
                return FailedStatus(self.engine.dead)
            if time.monotonic() > deadline:
                return TimeoutStatus(waited_s=timeout_s)
            b.wait(nbb.BUFFER_EMPTY)

    def adopt(self, old: "Session") -> None:
        """Migrate a pre-restart session's state into this one
        (DESIGN.md §14): live handles re-home here (their ``_session``
        is re-pointed so polling drains THIS engine's rings), the
        terminal-dedupe set and completed queue carry over, and — when
        this engine was restored from a snapshot — every live handle is
        re-bound to its restored Request.  The old session is left
        closed and empty; adopting is idempotent."""
        if old is self:
            return
        self._finalized |= old._finalized
        self._completed.extend(old._completed)
        for rid, h in list(old._handles.items()):
            h._session = self
            self._handles[rid] = h
            m = rid & _REQ_MASK
            if m in self._by_mask and self._by_mask[m] is not h:
                self._by_mask.pop(m)    # wire-id collision: same rule
            else:                       # as submit_i — disable both
                self._by_mask[m] = h
        old._handles.clear()
        old._by_mask.clear()
        old._completed.clear()
        old._finalized = set()
        old.closed = True
        if self.engine.restore_report is not None:
            self._rebind_restored()

    def _rebind_restored(self) -> None:
        """Post-restore handle reconciliation: a live handle whose
        request survived into the snapshot (or replayed from the WAL)
        is re-pointed at the restored Request object — ``cancel()`` must
        CAS the FSM the engine actually schedules.  A handle the
        restored engine does not know (accepted after the last snapshot
        without a surviving WAL record) finalizes NOW with a typed falsy
        FailedStatus: its request is gone; waiting would hang forever."""
        eng = self.engine
        report = eng.restore_report
        for rid, h in list(self._handles.items()):
            if h._final is not None:
                continue
            new_req = eng._restored_reqs.get(rid)
            if new_req is not None:
                if new_req is not h.req:
                    h.req = new_req
                continue
            req = h.req
            req.status = FailedStatus("lost across restart")
            if not req.fsm.cas(states.REQUEST_VALID,
                               states.REQUEST_CANCELLED):
                req.fsm.cas(states.REQUEST_RECEIVED,
                            states.REQUEST_CANCELLED)
            if req.done_t == 0.0:
                req.done_t = time.monotonic()
            if req.tokens_out is None:
                req.tokens_out = np.zeros((0,), np.int32)
            h.status = req.status
            self.forget(rid)
            self._finalized.add(rid)
            h._final = req
            if report is not None:
                report["failed"] = int(report.get("failed", 0)) + 1

    def close(self) -> None:
        """Explicit teardown (idempotent): cancel every in-flight
        handle, pump once so already-delivered terminals land, then
        refuse further submits (they get already-terminal FailedStatus
        handles).  The engine reclaims the cancelled requests' slots and
        pages on its next tick — close never blocks on the batcher, and
        the engine's delivery paths drop this client's traffic instead
        of retrying into rings nobody drains."""
        if self.closed:
            return
        for h in list(self._handles.values()):
            h.cancel()
        self.pump()
        self.closed = True
        self._handles.clear()
        self._by_mask.clear()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


@dataclasses.dataclass
class DecodeSlot:
    """One row of the persistent batch cache, owned by at most one
    sequence at a time.  ``fsm`` is the paper's Figure-4 buffer cell —
    every occupancy change is a CAS transition, so a scheduler bug that
    double-books or early-frees a slot raises instead of corrupting KV."""

    index: int
    fsm: states.StateCell = dataclasses.field(
        default_factory=lambda: states.buffer_cell())
    request: Optional[Request] = None
    next_tok: int = 0                   # token produced, not yet harvested
    pos: int = 0                        # tokens written to this row's cache
    generated: int = 0
    outs: Optional[np.ndarray] = None
    prompt: Optional[np.ndarray] = None  # bucketed prompt being prefilled
    prefill_pos: int = 0                # prompt tokens streamed so far
    # Prefix sharing (slot_paged + prefix cache, DESIGN.md §11): the
    # bound prompt's chained chunk hashes (registered in-flight so burst
    # duplicates defer instead of prefilling cold) and the not-yet-
    # cacheable (ready_at, key, n_tokens) insertions, consumed in order
    # as the written extent passes each entry's last page.
    chunk_hashes: Optional[List[int]] = None
    pending_prefix: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    # Keys whose cache entries THIS binding created — rolled back on
    # abort/reject so an all-or-nothing admission leaves no residue.
    created_prefixes: List[int] = dataclasses.field(default_factory=list)
    # Overload control (DESIGN.md §12): a just-resumed slot is immune to
    # re-preemption until it has decoded at least one block — without
    # this, a high-priority flood could swap the same victim in and out
    # every tick, paying swap traffic for zero forward progress.
    fresh_resume: bool = False


@dataclasses.dataclass
class ParkedSeq:
    """A preempted sequence parked off-slot (DESIGN.md §12): the host
    :class:`SwapImage` holding its private KV pages, plus everything the
    decode slot held so a resume restores the exact mid-decode state
    (the greedy continuation is byte-identical — block-table indirection
    makes the new physical page numbers invisible).  ``fsm`` is the
    sequence's Figure-4 buffer cell, parked in BUFFER_PREEMPTED; it
    travels with the sequence, and the vacated slot gets a fresh FREE
    cell.  ``bypassed`` counts resume attempts lost to more urgent
    intake — at the policy's aging limit the sequence is promoted
    (eff_priority 0) so preemption cannot starve it."""

    req: Request
    image: SwapImage
    prompt: np.ndarray
    outs: np.ndarray
    generated: int
    pos: int
    cur: int                            # last sampled token (resume feed)
    fsm: states.StateCell
    chunk_hashes: Optional[List[int]]
    pending_prefix: List[Tuple[int, int, int]]
    created_prefixes: List[int]
    bypassed: int = 0


def _write_slot_caches(full, one, slot):
    """Copy a B=1 prefilled cache into row ``slot`` of the batch cache.

    The batch axis of each leaf is located structurally: it is the single
    axis where the full cache is wider than the single-sequence cache
    (works for every cache family — attention rings, mamba/rwkv state,
    nested superblocks — without per-family code)."""
    def put(f, o):
        if f.shape == o.shape:          # max_batch == 1
            return o
        diff = [i for i in range(f.ndim) if f.shape[i] != o.shape[i]]
        assert len(diff) == 1 and o.shape[diff[0]] == 1, (f.shape, o.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=diff[0])
    return jax.tree.map(put, full, one)


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 128, n_clients: int = 2,
                 pool_pages: int = 64, page_size: int = 16,
                 intake_depth: int = 32, stream_depth: int = 256,
                 scheduler: str = "slot_fused", k_max: int = 8,
                 k_free: int = 2, chunk_tokens: int = 16,
                 prefix_cache: bool = True,
                 overload: Optional[OverloadPolicy] = None,
                 fault_plan: Optional["faults_mod.FaultPlan"] = None,
                 lease_s: Optional[float] = None, tick_retries: int = 1,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: Optional[int] = None):
        if scheduler not in ("slot_paged", "slot_chunked", "slot_fused",
                             "slot", "wave"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if k_max < 1 or k_free < 1:
            raise ValueError(f"need k_max >= 1 and k_free >= 1, "
                             f"got {k_max}/{k_free}")
        if not 1 <= chunk_tokens <= max_len:
            raise ValueError(f"need 1 <= chunk_tokens <= max_len, "
                             f"got {chunk_tokens}/{max_len}")
        if scheduler == "slot_chunked" and not model.chunkable:
            raise ValueError(
                f"{model.cfg.name}: slot_chunked needs position-indexed "
                "caches (recurrent mamba/rwkv state cannot be chunk-"
                "prefilled in place); use scheduler='slot_fused'")
        if scheduler == "slot_paged" and not model.pageable:
            raise ValueError(
                f"{model.cfg.name}: slot_paged needs one uniform position-"
                "indexed KV shape per layer (no sliding window, no "
                "recurrent/cross state); use scheduler='slot_chunked'")
        if (overload is not None and overload.preemption
                and scheduler != "slot_paged"):
            raise ValueError(
                "overload.preemption needs scheduler='slot_paged': page-"
                "swap preemption parks pool pages behind the block table; "
                "the dense schedulers have no swappable residency")
        self.model, self.params = model, params
        self.max_batch, self.max_len = max_batch, max_len
        self.scheduler = scheduler
        self.chunk_tokens = chunk_tokens
        # k_max=1 is the legitimate scalar-equivalent fused setting;
        # clamp the under-capacity cap instead of rejecting it.
        self.k_max, self.k_free = k_max, min(k_free, k_max)
        cfg = model.cfg
        # Overload control (DESIGN.md §12): with a policy installed the
        # flat MPSC fan-in becomes the multi-class weighted-fair intake
        # (same lock-free per-client SPSC rings, one set per class).
        self._ov = overload
        self._intake_depth = intake_depth
        self._stream_depth = stream_depth
        self.intake = (PriorityIntake(n_clients, overload, intake_depth)
                       if overload is not None else
                       MpscQueue(n_clients,
                                 capacity_per_producer=intake_depth))
        self.responses = [SpscQueue(intake_depth) for _ in range(n_clients)]
        # Per-token scalars ride a separate SPSC ring so a slow streaming
        # consumer can never wedge terminal delivery (tokens are lossy
        # under backpressure, terminals are not — DESIGN.md §5).
        self.streams = [SpscQueue(stream_depth) for _ in range(n_clients)]
        self._sessions = [Session(self, c) for c in range(n_clients)]
        self.pool = PagedKVPool(
            pool_pages, page_size, n_layers=cfg.num_layers,
            kv_heads=max(cfg.num_kv_heads, 1), head_dim=cfg.head_dim_ or 1,
            dtype=cfg.compute_dtype)
        self._id = itertools.count()
        self._stop = threading.Event()
        self._jit_decode = jax.jit(model.decode_step)
        # Fused K-step decode traces, one per K actually used (K is a
        # static scan length).  The caches are donated: each block's
        # input cache buffers are reused for its output, so the
        # persistent [max_batch, ...] cache is never copied per block.
        self._jit_loops: Dict[int, object] = {}
        # Chunked admission traces, one per K (0 = chunk-only, no active
        # decode rows).  The fixed [B, chunk_tokens] chunk shape bounds
        # the trace count at k_max + 2 regardless of prompt lengths.
        self._jit_chunked: Dict[int, object] = {}
        self._jit_write_slot = jax.jit(_write_slot_caches)
        # One jitted prefill; jax specializes it per (batch, prompt) shape.
        self._jit_prefill = jax.jit(
            lambda p, t: model.prefill(p, t, self.max_len))
        # Slot state (iteration-level scheduler).
        self.slots = [DecodeSlot(i) for i in range(max_batch)]
        self._caches = None             # persistent [max_batch, ...] cache
        # Paged residency (slot_paged): per-slot block-table width.  The
        # dense batch cache is never allocated; slots are int32 rows.
        self._max_pages = self.pool.pages_needed(max_len)
        self._cur = np.zeros((max_batch,), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        # Prefix sharing (DESIGN.md §11): chained chunk hashes of the
        # bucketed prompt stream -> resident page runs.  Only the paged
        # scheduler can share (dense rows are per-slot by construction).
        self.prefix_cache = (PrefixCache(self.pool)
                             if scheduler == "slot_paged" and prefix_cache
                             else None)
        # Burst dedup: requests whose whole shareable prefix is being
        # prefilled by a bound slot RIGHT NOW wait here instead of
        # prefilling the same chunks cold (batcher-thread only).
        self._deferred: List[Tuple[Request, List[int]]] = []
        self._inflight: Dict[int, int] = {}   # chunk hash -> bound slots
        self._pending_bind: Dict[int, Tuple[List[int], int]] = {}
        # Preempted sequences parked off-slot, and per-class TTFT
        # samples (batcher-thread only).
        self._parked: List[ParkedSeq] = []
        self._ttft_by_class: Dict[int, List[float]] = {}
        self.stats = {"served": 0, "rejected": 0, "cancelled": 0,
                      "batches": 0, "decode_steps": 0, "admitted": 0,
                      "prefills": 0, "slot_busy_steps": 0,
                      "dropped_responses": 0, "dropped_stream_events": 0,
                      "host_syncs": 0, "ring_ops": 0, "fused_blocks": 0,
                      # Admission-plane counters (DESIGN.md §9), honest
                      # for every scheduler: device dispatches that
                      # carried prefill work, prompt chunks materialized
                      # (monolithic prefill = one whole-prompt chunk),
                      # extra dispatches that only copy a side cache into
                      # the batch cache (zero for slot_chunked), and
                      # decode-step opportunities active slots lost while
                      # a serial prefill ran (zero for slot_chunked:
                      # chunks ride the decode dispatch).
                      "prefill_dispatches": 0, "prefill_chunks": 0,
                      "cache_copy_dispatches": 0,
                      "admission_stall_steps": 0,
                      # Prefix-sharing counters (DESIGN.md §11):
                      # admissions that adopted cached pages and the
                      # prompt positions those hits never dispatched.
                      "prefix_hits": 0, "prefill_tokens_saved": 0,
                      # Overload-control counters (DESIGN.md §12):
                      # page-swap preemptions/resumes (swap bytes mirror
                      # the pool's itemized counters) and requests shed
                      # at admission past their SLO.
                      "preemptions": 0, "resumes": 0, "shed_requests": 0,
                      "swap_in_bytes": 0, "swap_out_bytes": 0,
                      # Robustness counters (DESIGN.md §13): faults the
                      # armed plan fired, requests the ENGINE terminated
                      # (watchdog/lease/poison — distinct from client
                      # cancels and admission rejects), leases reaped,
                      # and pages quarantined after poisoned writes.
                      "faults_injected": 0, "requests_failed": 0,
                      "leases_reaped": 0, "pages_quarantined": 0,
                      # Crash-recovery counters (DESIGN.md §14):
                      # snapshots written / bytes of the newest one,
                      # restores performed, journal records replayed as
                      # fresh submissions, and in-process restarts.
                      "snapshots": 0, "snapshot_bytes": 0, "restores": 0,
                      "replayed_requests": 0, "restarts": 0}
        # Append-only log of fail-fast oversize rejects (written by
        # client threads in submit_i; list.append is the atomic).
        self.oversize_log: List[int] = []
        # -- robustness layer (DESIGN.md §13) ------------------------------
        if lease_s is not None and lease_s <= 0:
            raise ValueError(f"lease_s must be positive, got {lease_s}")
        if tick_retries < 0:
            raise ValueError(f"tick_retries must be >= 0, "
                             f"got {tick_retries}")
        self.faults = fault_plan
        self.pool.faults = fault_plan
        self.lease_s = lease_s
        self.tick_retries = int(tick_retries)
        # Set once by _die(): the engine can no longer make progress;
        # every receive surface observes it and resolves with a typed
        # falsy FailedStatus instead of hanging.
        self.dead: Optional[str] = None
        self._tick_failures = 0         # consecutive failed ticks (watchdog)
        self._reaped: set = set()       # clients whose lease was reaped
        # -- crash recovery (DESIGN.md §14) --------------------------------
        if snapshot_dir is not None and scheduler != "slot_paged":
            raise ValueError(
                "snapshot_dir needs scheduler='slot_paged': snapshots "
                "image the paged pool's block tables and pages; the "
                "dense schedulers have no host-recoverable KV state")
        if snapshot_every is not None and snapshot_dir is None:
            raise ValueError("snapshot_every requires snapshot_dir")
        if snapshot_every is not None and snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, "
                             f"got {snapshot_every}")
        self._snap_dir = snapshot_dir
        self._snap_every = snapshot_every
        self._snap_requested = False    # signal-handler-safe flag
        self._in_tick = False           # snapshots only at tick boundaries
        self._ticks = 0
        self._restart_count = 0         # in-process restarts (not restored)
        # Requests a restore re-queued ahead of the intake rings, in
        # deterministic order (snapshot-queued first, then journal
        # replay); consumed by _intake_recv before any ring pop.
        self._restore_queue: deque = deque()
        # req_id -> restored Request: what Session._rebind_restored uses
        # to re-point live handles after a restart.
        self._restored_reqs: Dict[int, Request] = {}
        self.restore_report: Optional[Dict[str, object]] = None
        self._journal: Optional[snapshot_mod.IntakeJournal] = None
        if snapshot_dir is not None:
            os.makedirs(snapshot_dir, exist_ok=True)
            self._journal = snapshot_mod.IntakeJournal(
                os.path.join(snapshot_dir, "journal.wal"))
        if fault_plan is not None:
            # Thread the plan through the engine's own delivery rings so
            # transport sites cover the token/terminal planes too (the
            # intake side probes in _intake_recv).
            self.streams = [
                transport.FaultyTransport(r, fault_plan, f"stream{c}")
                for c, r in enumerate(self.streams)]
            self.responses = [
                transport.FaultyTransport(r, fault_plan, f"responses{c}")
                for c, r in enumerate(self.responses)]

    # -- client API (one thread per client) -------------------------------------
    def connect(self, client_id: int,
                resume: Optional[Session] = None) -> Session:
        """The client's streaming session.  One per client: the session
        owns the consumer side of the client's response/stream rings, so
        all receive-side calls for a client must come from one thread.
        Connecting RE-OPENS a closed session: close() left nothing in
        flight, so the new holder starts clean with a fresh lease.

        ``resume`` re-binds a pre-restart session's live handles onto
        this engine (DESIGN.md §14): handles whose requests the restored
        engine knows keep streaming mid-decode (clients dedupe by the
        ``req_id|pos|token`` wire positions, so delivery stays
        exactly-once across the restart); the rest finalize with a typed
        falsy FailedStatus instead of hanging."""
        sess = self._sessions[client_id]
        if sess.closed:
            sess.closed = False
            sess.last_pump_t = time.monotonic()
        if resume is not None and resume is not sess:
            sess.adopt(resume)
        return sess

    def submit(self, client_id: int, prompt: np.ndarray,
               max_tokens: int = 16, eos_id: int = -1,
               priority: Optional[int] = None,
               slo_s: Optional[float] = None) -> Optional[Request]:
        """Non-blocking submit (legacy whole-response surface): a thin
        wrapper over ``Session.submit_i`` that detaches the handle, so
        the terminal Request is delivered through ``get_response``.
        None => intake ring full (caller retries)."""
        session = self._sessions[client_id]
        h = session.submit_i(prompt, max_tokens, eos_id,
                             priority=priority, slo_s=slo_s)
        if h.status is not None:
            # Rejected fast at the session layer (oversize): route the
            # already-terminal Request to the legacy get_response queue.
            session._completed.append(h.response)
            return h.req
        if not h.submitted:
            h.cancel()                  # abandon the pending send ...
            h.test()                    # ... and finalize it (owner thread)
            return None
        session.forget(h.req_id)
        return h.req

    # -- shared helpers -----------------------------------------------------------
    def _respond(self, req: Request) -> None:
        # Response ring full => bounded backoff, never a spin-pin.  The
        # send can only fail during shutdown (should_stop); record the
        # drop so stats never silently overcount deliveries.  A client
        # presumed dead (reaped lease), a closed session, or a dead
        # engine gets a short timeout instead of an unbounded retry —
        # nobody drains that ring, and the batcher must not wedge on it
        # (handles resolve through Request.status / engine.dead anyway).
        self.stats["ring_ops"] += 1
        abandoned = (self.dead is not None
                     or req.client_id in self._reaped
                     or self._sessions[req.client_id].closed)
        if not transport.send_blocking(self.responses[req.client_id], req,
                                       timeout_s=0.05 if abandoned else None,
                                       should_stop=self._stop.is_set):
            self.stats["dropped_responses"] += 1

    def _stream_tokens(self, req: Request, first_pos: int, toks) -> None:
        """Best-effort packet-mode delivery: the whole harvested block
        for one request rides the client's stream ring as ONE burst (one
        counter announce/commit pair) instead of ``len(toks)`` scalar
        exchanges — the paper's packet-vs-scalar amortization applied to
        the token plane.  Backpressure stays pure: whatever suffix does
        not fit is dropped (counted), and every dropped position is
        still delivered exactly once at completion via ``tokens_out``
        (handles fill the gaps)."""
        if (self._sessions[req.client_id].closed
                or req.client_id in self._reaped):
            # Nobody drains this stream ring anymore: dropping beats
            # filling a ring whose consumer is gone.
            self.stats["dropped_stream_events"] += len(toks)
            return
        evs = [pack_token_event(req.req_id, first_pos + j, int(t))
               for j, t in enumerate(toks)]
        _, n = self.streams[req.client_id].send_burst(evs)
        self.stats["ring_ops"] += 1
        if n < len(evs):
            self.stats["dropped_stream_events"] += len(evs) - n

    def _reject(self, req: Request) -> None:
        # A concurrent client cancel() may have won the CAS already; the
        # request still gets exactly one terminal response either way.
        if req.fsm.cas(states.REQUEST_VALID, states.REQUEST_CANCELLED):
            self.stats["rejected"] += 1
        else:
            self.stats["cancelled"] += 1
        req.done_t = time.monotonic()
        if req.tokens_out is None:      # consistent terminal: empty, not None
            req.tokens_out = np.zeros((0,), np.int32)
        self._respond(req)

    def _finish_cancelled(self, req: Request) -> None:
        """Terminal delivery for a request the client cancelled before it
        reached a decode slot."""
        req.done_t = time.monotonic()
        if req.tokens_out is None:
            req.tokens_out = np.zeros((0,), np.int32)
        self.stats["cancelled"] += 1
        self._respond(req)

    # -- self-healing (fault injection + recovery, DESIGN.md §13) --------------
    @staticmethod
    def _raw_ring(t):
        """The counter ring under a FaultyTransport wrapper (or ``t``
        itself): recovery code operates on the real ring, not through
        the fault layer."""
        return getattr(t, "inner", t)

    def _fault_raise(self, site: str, retryable: bool = True) -> None:
        """Engine-side injection probe (dispatch/sync sites)."""
        if self.faults is not None and self.faults.fire(site) is not None:
            raise faults_mod.InjectedFault(site, self.faults.n_fired,
                                           retryable=retryable)

    def _paused_plan(self):
        """Context: suspend fault firing while recovery code runs, so
        cleanup never recurses into fresh injected faults."""
        return (self.faults.pause() if self.faults is not None
                else contextlib.nullcontext())

    def _fail_slot(self, slot: DecodeSlot, reason: str) -> None:
        """Engine-initiated terminal for a bound slot (watchdog fail-all,
        lease reap, poisoned write): the mirror of ``_abort_slot`` with a
        typed FailedStatus and the ``requests_failed`` counter.  Partial
        output is delivered; cache insertions this binding created are
        rolled back and the pages freed — pool state returns exactly to
        pre-admission (minus any pages quarantine pinned first)."""
        req = slot.request
        req.status = FailedStatus(reason)
        req.tokens_out = slot.outs[:slot.generated].astype(np.int32)
        req.done_t = time.monotonic()
        if req.fsm.cas(states.REQUEST_RECEIVED, states.REQUEST_CANCELLED):
            self.stats["requests_failed"] += 1
        else:
            self.stats["cancelled"] += 1    # client cancel won the race
        self._rollback_created(slot)
        self.pool.free(req.req_id)
        self._respond(req)
        self._release_slot(slot)

    def _fail_queued(self, req: Request, reason: str) -> None:
        """Engine-initiated terminal for a request that never reached a
        slot (lease reap / dead-engine intake drain)."""
        req.status = FailedStatus(reason)
        if req.fsm.cas(states.REQUEST_VALID, states.REQUEST_CANCELLED):
            self.stats["requests_failed"] += 1
        else:
            self.stats["cancelled"] += 1
        req.done_t = time.monotonic()
        if req.tokens_out is None:
            req.tokens_out = np.zeros((0,), np.int32)
        self._respond(req)

    def _client_rings(self, client_id: int) -> List[object]:
        """The client's private intake ring(s): one flat MPSC ring, or
        one per priority class under an overload policy."""
        if self._ov is None:
            return [self.intake.producer(client_id)]
        return [q.producer(client_id) for q in self.intake._queues]

    def _reap_leases(self) -> bool:
        """Per-session leases (DESIGN.md §13): a client silent past
        ``lease_s`` — no pump, no submit — is presumed dead.  Its whole
        stake is reclaimed in one sweep: bound slots and parked images
        fail with a typed terminal, queued submissions (including a span
        its dying thread announced but never committed —
        ``recover_ring`` is legal exactly because the lease declared the
        producer dead) are drained and failed, and the engine adopts the
        consumer side of the abandoned stream ring so stale events can't
        pin it.  A client that pumps again after reaping simply renews
        its lease and keeps using the session."""
        now = time.monotonic()
        worked = False
        for sess in self._sessions:
            c = sess.client_id
            if now - sess.last_pump_t <= self.lease_s:
                self._reaped.discard(c)     # heartbeat seen: renewed
                continue
            if c in self._reaped:
                continue
            self._reaped.add(c)     # responses to it are now time-bounded
            reason = (f"lease expired: client {c} silent > "
                      f"{self.lease_s:g}s")
            with self._paused_plan():
                had = False
                for slot in self.slots:
                    if (slot.request is not None
                            and slot.request.client_id == c):
                        self._fail_slot(slot, reason)
                        had = True
                for parked in [p for p in self._parked
                               if p.req.client_id == c]:
                    parked.req.status = FailedStatus(reason)
                    self._discard_parked(parked, failed=True)
                    self._parked.remove(parked)
                    had = True
                keep: List[Tuple[Request, List[int]]] = []
                for req, keys in self._deferred:
                    if req.client_id == c:
                        self._fail_queued(req, reason)
                        had = True
                    else:
                        keep.append((req, keys))
                self._deferred = keep
                for ring in self._client_rings(c):
                    faults_mod.recover_ring(ring)
                    for req in ring.drain_burst():
                        self._fail_queued(req, reason)
                        had = True
                if self._raw_ring(self.streams[c]).drain_burst():
                    had = True
            if had:
                self.stats["leases_reaped"] += 1
                worked = True
        return worked

    def _on_tick_fault(self, exc: Exception) -> Tuple[int, bool]:
        """The tick watchdog's catch half.  A retryable fault (an
        injected dispatch refusal, or any exception not marked
        otherwise) earns up to ``tick_retries`` whole-tick retries —
        pre-dispatch host bookkeeping is idempotent, so the retry simply
        reassembles and redispatches.  Past that (or on a non-retryable
        sync fault, where the device advanced beyond what the host
        harvested) every bound slot fails with a typed terminal and the
        engine KEEPS SERVING: queued and future requests are unaffected.
        The engine's own rings are rolled back from any announced-but-
        uncommitted span first — the engine thread is their producer, so
        the rollback is unconditionally legal."""
        retryable = bool(getattr(exc, "retryable", True))
        self._tick_failures += 1
        if retryable and self._tick_failures <= self.tick_retries:
            return 0, True              # transient: next tick retries
        self._tick_failures = 0
        reason = f"tick failed: {exc!r}"
        with self._paused_plan():
            for t in list(self.streams) + list(self.responses):
                faults_mod.recover_ring(self._raw_ring(t))
            for slot in self.slots:
                if slot.request is None:
                    continue
                try:
                    self._fail_slot(slot, reason)
                except Exception:       # never re-raise out of tick
                    pass
        return 0, True

    def _die(self, reason: str) -> None:
        """Terminal engine failure (the loop itself crashed — beyond
        what fail-all-and-continue can heal): record the cause, resolve
        EVERY outstanding request with a typed falsy terminal, and leave
        ``dead`` set so every receive surface (handle ``wait``,
        ``next_response``/``get_response``) returns immediately instead
        of hanging on an engine that will never answer."""
        if self.dead is not None:
            return
        if self._snap_dir is not None and not self._in_tick:
            # Last-gasp checksummed snapshot (DESIGN.md §14), attempted
            # only at a consistent boundary (mid-tick state may be half-
            # harvested — then the last periodic snapshot stands).  The
            # fault plan is NOT paused here: a snapshot.write fault can
            # tear this file, and the loader's checksum falls back to
            # the previous good one — that path is part of the contract.
            with contextlib.suppress(Exception):
                self.save_snapshot()
        self.dead = reason
        self._stop.set()
        with self._paused_plan():
            for t in list(self.streams) + list(self.responses):
                faults_mod.recover_ring(self._raw_ring(t))
            for slot in self.slots:
                if slot.request is None:
                    continue
                try:
                    self._fail_slot(slot, reason)
                except Exception:
                    pass
            for parked in list(self._parked):
                parked.req.status = FailedStatus(reason)
                try:
                    self._discard_parked(parked, failed=True)
                except Exception:
                    pass
                self._parked.remove(parked)
            for req, _ in self._deferred:
                self._fail_queued(req, reason)
            self._deferred = []
            while True:
                status, req = self._intake_recv()
                if status != nbb.OK or req is None:
                    break
                self._fail_queued(req, reason)
        if self.faults is not None:
            self.stats["faults_injected"] = self.faults.n_fired

    def fault_report(self) -> Dict[str, object]:
        """Robustness snapshot (printed by launch/serve.py): the four
        §13 counters plus the fired-site log and death reason."""
        if self.faults is not None:
            self.stats["faults_injected"] = self.faults.n_fired
        return {
            "faults_injected": self.stats["faults_injected"],
            "requests_failed": self.stats["requests_failed"],
            "leases_reaped": self.stats["leases_reaped"],
            "pages_quarantined": self.stats["pages_quarantined"],
            "quarantined_pages": sorted(self.pool.quarantined),
            "dead": self.dead,
            "fired_sites": (list(self.faults.fired)
                            if self.faults is not None else []),
        }

    # ===========================================================================
    # Iteration-level scheduler (default): slot swap, no wave barrier.
    # ===========================================================================
    def _bucket(self, n: int) -> int:
        """Pad prompts to power-of-two buckets (>=8) to bound the number
        of prefill traces; left-padding matches the wave scheduler.  The
        chunked scheduler streams the same bucketed prompt (so its token
        sequences stay byte-identical to the other slot schedulers) but
        through ONE fixed [B, chunk_tokens] trace — the bucket no longer
        multiplies compiled programs, only chunk count."""
        b = 8
        while b < n:
            b *= 2
        return b

    def _padded_prompt(self, req: Request) -> np.ndarray:
        """The bucketed, left-padded token stream a slot actually
        prefills — also the stream prefix hashes are computed over, so
        padding is part of the hashed content (DESIGN.md §11)."""
        padded = self._bucket(len(req.prompt))
        prompt = np.zeros((padded,), np.int32)
        prompt[padded - len(req.prompt):] = req.prompt      # left-pad
        return prompt

    def _footprint(self, prompt_len: int) -> int:
        """Cache positions a prompt occupies before generation starts,
        for the session layer's fail-fast oversize check: the bucketed
        length for the slot schedulers (they really write at bucketed
        positions), the raw length for the wave scheduler (it pads only
        to the batch max and self-truncates decode at ``max_len``, so
        bucketing would reject requests it used to serve)."""
        if self.scheduler == "wave":
            return prompt_len
        return self._bucket(prompt_len)

    def _ensure_caches(self) -> None:
        if self._caches is None:
            self._caches = self.model.init_cache(self.max_batch, self.max_len)

    def dense_cache_bytes(self) -> int:
        """Footprint the dense [max_batch, max_len] batch cache WOULD
        occupy (abstract eval — nothing is allocated): the honest
        baseline the paged scheduler's ``kv_resident_bytes`` is compared
        against."""
        shapes = jax.eval_shape(
            lambda: self.model.init_cache(self.max_batch, self.max_len))
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(shapes)))

    # -- paged residency (scheduler="slot_paged", DESIGN.md §10) ---------------
    def _block_table(self) -> np.ndarray:
        """Assemble this dispatch's [max_batch, max_pages] block table
        from the pool's per-sequence page lists.  This int32 write IS
        the whole "swap-in": residency never moves KV bytes.  Rows of
        free slots stay 0 — their writes are masked on device and their
        reads causally masked to length 0."""
        bt = np.zeros((self.max_batch, self._max_pages), np.int32)
        for s in self.slots:
            if s.request is not None:
                pages = self.pool.table(s.request.req_id).pages
                bt[s.index, :len(pages)] = pages
        return bt

    def _take_caches(self):
        """The cache operand for this dispatch: the persistent dense
        batch cache, or (paged) a view of the pool's page arrays + the
        block table.  Both are donated to the dispatch."""
        if self.scheduler == "slot_paged":
            return {"pages_k": self.pool.k, "pages_v": self.pool.v,
                    "block": jnp.asarray(self._block_table())}
        self._ensure_caches()
        return self._caches

    def _give_caches(self, caches) -> None:
        """Re-adopt the dispatch's (donated, updated in place) cache
        buffers: the pool arrays for paged, the batch cache otherwise."""
        if self.scheduler == "slot_paged":
            self.pool.k = caches["pages_k"]
            self.pool.v = caches["pages_v"]
        else:
            self._caches = caches

    def _pop_next(self, slot: DecodeSlot) -> Optional[Request]:
        """Pop the next admissible request for ``slot``: pool-full
        requests are rejected (the NBB BUFFER_FULL discipline), requests
        cancelled while queued are answered with their empty terminal —
        the batcher never blocks behind either.  Returns None when the
        intake fan-in is empty.

        Page claim at admission: the full prompt+generation reservation
        for the monolithic-prefill schedulers; only the FIRST CHUNK for
        ``slot_chunked`` — the rest of the reservation is extended chunk
        by chunk as positions materialize (DESIGN.md §9).

        With the prefix cache on (``slot_paged``), a cached prefix hit
        skips those chunks entirely: admission adopts the cached pages
        (refcount increments + an int32 block-table row — no device
        dispatch, no claim that can fail) and prefill resumes at the hit
        extent (DESIGN.md §11)."""
        while True:
            req, keys = self._next_candidate()
            if req is None:
                return None
            if self._ov is not None and self._should_shed(req):
                self._shed(req)
                continue
            padded = self._bucket(len(req.prompt))
            entry = None
            if keys:
                usable = self._usable_keys(padded, keys)
                if usable:
                    entry = self.prefix_cache.lookup(usable[::-1])
            if entry is not None:
                self.pool.adopt_shared(req.req_id, entry.pages,
                                       entry.n_tokens, slot=slot.index)
            else:
                if self.scheduler in ("slot_chunked", "slot_paged"):
                    need = min(self.chunk_tokens, padded)
                else:
                    need = padded + req.max_tokens
                if not self._claim_admit(req, need, slot.index):
                    self._reject(req)
                    continue
            if not req.fsm.cas(states.REQUEST_VALID, states.REQUEST_RECEIVED):
                # Client cancelled while queued: give the pages straight
                # back and answer with the (empty) terminal.  For a hit
                # that is pure refcount decrements — the cached prefix
                # stays resident for the next request.
                self.pool.free(req.req_id)
                self._finish_cancelled(req)
                continue
            if keys is not None:
                e_hit = entry.n_tokens if entry is not None else 0
                self._pending_bind[req.req_id] = (keys, e_hit)
                if entry is not None:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefill_tokens_saved"] += e_hit
            return req

    def _usable_keys(self, padded: int, keys: List[int]) -> List[int]:
        """Hit candidates among a prompt's chained chunk hashes,
        ascending depth.  Two caps: a hit must leave at least one prompt
        token to dispatch (the final chunk computes the first output
        token — a fully cached prompt still owes one forward pass), and
        must cover at least one full page (sub-page sharing buys a
        whole-page CoW copy to reuse less than a page of KV — a net
        loss, so those prefixes are neither offered nor taken)."""
        C, ps = self.chunk_tokens, self.pool.page_size
        return [keys[d] for d in range(len(keys))
                if ps <= (d + 1) * C < padded]

    def _defer_blocked(self, req: Request, keys: List[int]) -> bool:
        """True while some bound slot is prefilling this request's whole
        shareable prefix RIGHT NOW: admitting it cold would duplicate
        those chunk dispatches, so it waits for the cache entries the
        in-flight slot will publish.  Unblocks the moment the deepest
        shareable hash is cached (hit) or its writer unbinds (cold)."""
        usable = self._usable_keys(self._bucket(len(req.prompt)), keys)
        if not usable:
            return False
        deepest = usable[-1]
        return (deepest not in self.prefix_cache
                and self._inflight.get(deepest, 0) > 0)

    def _next_candidate(self) -> Tuple[Optional[Request],
                                       Optional[List[int]]]:
        """Next admission candidate: an unblocked deferred request
        first, else the intake fan-in (burst duplicates of an in-flight
        prefix are parked in ``_deferred`` instead of returned)."""
        if self.prefix_cache is not None:
            for i, (req, keys) in enumerate(self._deferred):
                if (req.fsm.state != states.REQUEST_VALID
                        or not self._defer_blocked(req, keys)):
                    del self._deferred[i]
                    return req, keys
        while True:
            status, req = self._intake_recv()
            if status != nbb.OK:
                return None, None
            if self.prefix_cache is None:
                return req, None
            keys = prefix_chunk_hashes(self._padded_prompt(req),
                                       self.chunk_tokens)
            if (req.fsm.state == states.REQUEST_VALID
                    and self._defer_blocked(req, keys)):
                self._deferred.append((req, keys))
                continue
            return req, keys

    def _bind_slot(self, slot: DecodeSlot, req: Request) -> None:
        """Figure-4 head shared by all slot schedulers: FREE -> RESERVED
        (pages claimed), the bucketed prompt staged for prefill."""
        slot.fsm.transition(states.BUFFER_FREE, states.BUFFER_RESERVED)
        prompt = self._padded_prompt(req)
        slot.request = req
        slot.prompt = prompt
        slot.prefill_pos = 0
        slot.pos = 0
        slot.generated = 0
        slot.outs = np.full((req.max_tokens,), -1, np.int64)
        self._pos[slot.index] = 0
        self._cur[slot.index] = 0
        self.stats["admitted"] += 1
        if self._ov is not None:
            # WFQ accounting at BIND, not pop: only work that actually
            # claims capacity advances the client's virtual time, and
            # the cost is the KV footprint it will occupy.
            self.intake.charge(req.client_id, len(prompt) + req.max_tokens)
        info = self._pending_bind.pop(req.req_id, None)
        if info is not None:
            keys, e_hit = info
            slot.chunk_hashes = keys
            # Register the chain in-flight: burst duplicates defer on it
            # instead of prefilling the same chunks cold (_sweep_in's
            # admission loop runs on the batcher thread only).
            for h in keys:
                self._inflight[h] = self._inflight.get(h, 0) + 1
            # Schedule cache insertions: entry d becomes cacheable when
            # the written extent passes its last page (ready_at), so the
            # writer never CoWs its own tail against the cache's refs.
            C, ps = self.chunk_tokens, self.pool.page_size
            slot.pending_prefix = [
                (math.ceil((d + 1) * C / ps) * ps, keys[d], (d + 1) * C)
                for d in range(len(keys)) if (d + 1) * C >= ps]
            if e_hit:
                # The hit chunks never dispatch: prefill resumes at the
                # cached extent over the adopted (shared) pages.
                slot.prefill_pos = e_hit
        if self._journal is not None:
            self._journal_bind(req)

    def _prefill_slot(self, slot: DecodeSlot) -> None:
        """Monolithic admission tail (``slot``/``slot_fused``): one B=1
        prefill dispatch, one dedicated host sync for the first token,
        and one extra device dispatch copying the B=1 cache into the
        batch-cache row — the serializing intermediary the chunked
        scheduler deletes.  Every active slot loses one decode-step
        opportunity while this runs (``admission_stall_steps``)."""
        req = slot.request
        self.stats["admission_stall_steps"] += sum(
            1 for s in self.slots
            if s is not slot and s.request is not None and s.generated > 0)
        tok, one_cache = self._jit_prefill(self.params,
                                           jnp.asarray(slot.prompt[None]))
        self.stats["prefills"] += 1
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_chunks"] += 1   # one whole-prompt chunk
        self.stats["host_syncs"] += 1   # the int(...) fetch below
        self._ensure_caches()
        self._caches = self._jit_write_slot(self._caches, one_cache,
                                            jnp.int32(slot.index))
        self.stats["cache_copy_dispatches"] += 1
        # Honest copy accounting (DESIGN.md §10): the B=1 side cache is
        # KV traffic this scheduler pays to establish residency.
        self.pool.kv_copy_bytes += int(sum(
            leaf.nbytes for leaf in jax.tree.leaves(one_cache)))
        # ... -> ALLOCATED (KV materialized in this slot's cache rows).
        slot.fsm.transition(states.BUFFER_RESERVED, states.BUFFER_ALLOCATED)
        padded = len(slot.prompt)
        slot.next_tok = int(np.asarray(tok)[0])
        slot.pos = padded
        slot.prefill_pos = padded
        self._pos[slot.index] = padded
        self._cur[slot.index] = slot.next_tok

    def _release_slot(self, slot: DecodeSlot) -> None:
        """Figure-4 tail shared by retire and abort: the slot's occupancy
        ends, the row is clean for the next admission.  A slot aborted
        while its prompt was still streaming in (chunked admission) is
        still RESERVED and takes the direct RESERVED -> FREE edge."""
        if slot.fsm.state == states.BUFFER_RESERVED:
            slot.fsm.transition(states.BUFFER_RESERVED, states.BUFFER_FREE)
        else:
            slot.fsm.transition(states.BUFFER_ALLOCATED,
                                states.BUFFER_RECEIVED)
            slot.fsm.transition(states.BUFFER_RECEIVED, states.BUFFER_FREE)
        self._drop_inflight(slot.chunk_hashes)
        slot.chunk_hashes = None
        slot.pending_prefix = []
        slot.created_prefixes = []
        slot.request = None
        slot.outs = None
        slot.prompt = None
        slot.prefill_pos = 0
        self._cur[slot.index] = 0
        self._pos[slot.index] = 0

    def _drop_inflight(self, keys: Optional[List[int]]) -> None:
        """Deregister a binding's chunk-hash chain from the in-flight
        dedup map (slot release and preemption parking both end the
        chain's prefill claim)."""
        if keys:
            for h in keys:
                n = self._inflight.get(h, 0) - 1
                if n <= 0:
                    self._inflight.pop(h, None)
                else:
                    self._inflight[h] = n

    def _maybe_insert_prefixes(self, slot: DecodeSlot,
                               final: bool = False) -> None:
        """Publish a bound sequence's cacheable prefixes (DESIGN.md
        §11).  An entry becomes publishable when the written extent
        passes its last page — earlier, the still-writing owner would
        have to CoW its own tail page the moment the cache incref'd it,
        charging copies to the cold path sharing is supposed to spare.
        At retire (``final``) everything left publishes: the owner will
        never write again, and a partially filled trailing page is safe
        behind causal masking + the hitter-side CoW gate."""
        if self.prefix_cache is None or not slot.pending_prefix:
            return
        extent = max(slot.prefill_pos, slot.pos)
        pages = self.pool.table(slot.request.req_id).pages
        ps = self.pool.page_size
        while slot.pending_prefix:
            ready_at, key, n_tok = slot.pending_prefix[0]
            if not final and extent < ready_at:
                break
            if self.prefix_cache.insert(key, n_tok,
                                        pages[:math.ceil(n_tok / ps)]):
                slot.created_prefixes.append(key)
            slot.pending_prefix.pop(0)

    def _retire(self, slot: DecodeSlot) -> None:
        """End-of-step release: slot + KV pages return to the pool the
        moment a sequence finishes — the next tick can swap a waiting
        request in while the other slots keep decoding."""
        req = slot.request
        req.tokens_out = slot.outs[:slot.generated].astype(np.int32)
        req.done_t = time.monotonic()
        # A client cancel() can win the finish-line CAS; either way the
        # pages are freed exactly once, here, by the batcher.
        if req.fsm.cas(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED):
            self.stats["served"] += 1
        else:
            self.stats["cancelled"] += 1
        if self._ov is not None and req.first_token_t:
            self._ttft_by_class.setdefault(req.priority, []).append(
                req.first_token_t - req.submit_t)
        # Publish the remaining cacheable prefixes before the pages go
        # back: the sequence writes nothing further, so even entries
        # whose last page is partially filled are safe to share (a
        # hitter causally masks past its own extent and CoWs the page
        # before writing it).
        self._maybe_insert_prefixes(slot, final=True)
        self.pool.free(req.req_id)
        self._respond(req)
        self._release_slot(slot)

    def _abort_slot(self, slot: DecodeSlot) -> None:
        """Mid-decode cancellation: the client's CAS won, so retire the
        slot NOW — its KV pages return to the pool and the terminal
        (partial ``tokens_out``, state CANCELLED) is delivered."""
        req = slot.request
        req.tokens_out = slot.outs[:slot.generated].astype(np.int32)
        req.done_t = time.monotonic()
        self._rollback_created(slot)
        self.pool.free(req.req_id)
        self.stats["cancelled"] += 1
        self._respond(req)
        self._release_slot(slot)

    def _rollback_created(self, slot: DecodeSlot) -> None:
        """Abort half of the all-or-nothing discipline, cache side: the
        prefix entries THIS binding created are withdrawn (exactly one
        decref per page each — entries that merely bumped an existing
        key are untouched), so an aborted admission leaves the pool
        exactly as it found it.  Pages another sequence shares survive
        the decref; only unshared ones return to the free set."""
        if self.prefix_cache is not None:
            for key in slot.created_prefixes:
                self.prefix_cache.evict_key(key)
            slot.created_prefixes = []

    # -- overload control (DESIGN.md §12) --------------------------------------
    def _intake_recv(self) -> Tuple[int, Optional[Request]]:
        """One intake pop.  Under an overload policy this is the
        multi-class pop; a request served by AGING over a more urgent
        nonempty class is promoted (eff_priority 0) so the bypass that
        earned its turn also shields it from instant preemption."""
        if self._restore_queue:
            # Restored/replayed submissions admit ahead of the (fresh,
            # empty) intake rings, in the deterministic order restore
            # queued them — no fault probe: these already paid intake
            # once, in their previous life.
            return nbb.OK, self._restore_queue.popleft()
        if self.faults is not None and \
                self.faults.fire("transport.recv") is not None:
            return nbb.BUFFER_EMPTY, None   # injected: pop refused
        if self._ov is None:
            return self.intake.try_recv()
        status, req, promoted = self.intake.pop()
        if status == nbb.OK and promoted:
            req.eff_priority = 0
        return status, req

    def _should_shed(self, req: Request) -> bool:
        """SLO-aware admission: True when the request's TTFT deadline
        (its own ``slo_s``, else the policy default) already passed
        while it sat queued — serving it now would burn capacity on an
        answer the client has written off."""
        slo = req.slo_s if req.slo_s is not None else self._ov.slo_s
        return slo is not None and time.monotonic() - req.submit_t > slo

    def _shed(self, req: Request) -> None:
        """Shed at intake: typed falsy ShedStatus on the terminal, no
        pages claimed, no slot bound, no device work (the
        preemption-vs-reject rule's cheap arm: work not yet started is
        refused; work in flight is preempted, never discarded)."""
        slo = req.slo_s if req.slo_s is not None else self._ov.slo_s
        req.status = ShedStatus(time.monotonic() - req.submit_t, slo,
                                req.priority)
        if req.fsm.cas(states.REQUEST_VALID, states.REQUEST_CANCELLED):
            self.stats["shed_requests"] += 1
        else:
            self.stats["cancelled"] += 1    # client cancel won the race
        req.done_t = time.monotonic()
        req.tokens_out = np.zeros((0,), np.int32)
        self._respond(req)

    def _choose_victim(self, needer_cls: int) -> Optional[DecodeSlot]:
        """The slot to preempt so class ``needer_cls`` can run: strictly
        lower-priority than the needer (equal class never preempts —
        that way lies thrash), actively decoding (generated > 0: a
        mid-prefill slot has no harvested state to park), and not just
        resumed (``fresh_resume`` — one block of progress is guaranteed
        between swaps).  Among candidates: worst class first, then the
        fewest written tokens (cheapest swap), then youngest."""
        cands = [s for s in self.slots
                 if s.request is not None and s.generated > 0
                 and not s.fresh_resume
                 and s.request.eff_priority > needer_cls]
        if not cands:
            return None
        return max(cands, key=lambda s: (s.request.eff_priority, -s.pos,
                                         s.request.req_id))

    def _claim_admit(self, req: Request, need: int, slot_index: int) -> bool:
        """``try_admit`` with the preemption escape hatch: under pool
        pressure a lower-priority decoding slot is swapped out and the
        claim retried, so a high-priority arrival is admitted instead of
        rejected while cheaper work holds the pool."""
        while True:
            if self.pool.try_admit(req.req_id, need,
                                   slot=slot_index) == POOL_OK:
                return True
            if self._ov is None or not self._ov.preemption:
                return False
            victim = self._choose_victim(req.eff_priority)
            if victim is None or not self._preempt_slot(victim):
                return False

    def _extend_with_preemption(self, s: DecodeSlot, need: int) -> bool:
        """Chunk-assembly reservation growth with the same escape hatch.
        Victims are decoding rows (generated > 0), never the streaming
        slot itself, and the preempted row simply drops out of this
        tick's dispatch (``active`` is assembled afterwards)."""
        while True:
            if self.pool.extend_reservation(s.request.req_id,
                                            need) == POOL_OK:
                return True
            if self._ov is None or not self._ov.preemption:
                return False
            victim = self._choose_victim(s.request.eff_priority)
            if victim is None or not self._preempt_slot(victim):
                return False

    def _preempt_slot(self, slot: DecodeSlot) -> bool:
        """Park ``slot``'s sequence host-side (ALLOCATED -> PREEMPTED).

        The pool swaps out only the sequence's PRIVATE pages (shared
        prefix pages stay resident with their refcounts — the prefix
        cache never pays for someone else's preemption); the Figure-4
        cell travels with the parked sequence and the slot gets a fresh
        FREE cell, ready to bind the work that displaced it.

        False when an injected ``pool.swap_out`` fault lands: the probe
        raises *before* any pool mutation, so the victim keeps decoding
        untouched and callers treat the failure as "no victim found"."""
        req = slot.request
        try:
            image = self.pool.swap_out_preempt(req.req_id, slot.pos)
        except faults_mod.InjectedFault:
            return False                # pre-mutation: victim unharmed
        self.stats["host_syncs"] += 1   # the gather's device->host fetch
        slot.fsm.transition(states.BUFFER_ALLOCATED, states.BUFFER_PREEMPTED)
        self._parked.append(ParkedSeq(
            req=req, image=image, prompt=slot.prompt, outs=slot.outs,
            generated=slot.generated, pos=slot.pos,
            cur=int(self._cur[slot.index]), fsm=slot.fsm,
            chunk_hashes=slot.chunk_hashes,
            pending_prefix=list(slot.pending_prefix),
            created_prefixes=list(slot.created_prefixes)))
        self._drop_inflight(slot.chunk_hashes)
        slot.fsm = states.buffer_cell()
        slot.request = None
        slot.prompt = None
        slot.outs = None
        slot.generated = 0
        slot.pos = 0
        slot.prefill_pos = 0
        slot.next_tok = 0
        slot.chunk_hashes = None
        slot.pending_prefix = []
        slot.created_prefixes = []
        slot.fresh_resume = False
        self._cur[slot.index] = 0
        self._pos[slot.index] = 0
        self.stats["preemptions"] += 1
        self.stats["swap_out_bytes"] = self.pool.swap_out_bytes
        return True

    def _resume_parked(self, slot: DecodeSlot, parked: ParkedSeq) -> bool:
        """Swap a parked sequence back into ``slot`` (PREEMPTED ->
        ALLOCATED).  False on POOL_FULL with nothing changed — the
        image stays parked for a later attempt.  On success the slot
        adopts the parked cell and the exact mid-decode state, so the
        next block continues the greedy stream byte-identically."""
        req = parked.req
        if self.pool.swap_in_preempt(req.req_id, parked.image) != POOL_OK:
            return False
        parked.fsm.transition(states.BUFFER_PREEMPTED,
                              states.BUFFER_ALLOCATED)
        slot.fsm = parked.fsm
        slot.request = req
        slot.prompt = parked.prompt
        slot.outs = parked.outs
        slot.generated = parked.generated
        slot.pos = parked.pos
        slot.prefill_pos = len(parked.prompt)
        slot.next_tok = parked.cur
        slot.chunk_hashes = parked.chunk_hashes
        if parked.chunk_hashes:
            for h in parked.chunk_hashes:
                self._inflight[h] = self._inflight.get(h, 0) + 1
        slot.pending_prefix = parked.pending_prefix
        slot.created_prefixes = parked.created_prefixes
        slot.fresh_resume = True
        self._cur[slot.index] = parked.cur
        self._pos[slot.index] = parked.pos
        self.pool.table(req.req_id).slot = slot.index
        self.stats["resumes"] += 1
        self.stats["swap_in_bytes"] = self.pool.swap_in_bytes
        return True

    def _try_resume(self, slot: DecodeSlot) -> bool:
        """Offer a free slot to the most urgent parked sequence.  More
        urgent *intake* work wins the slot instead — but only
        ``aging_limit`` times, after which the parked sequence is
        promoted (it has progress invested; starving it while admitting
        fresh work forever would waste everything already decoded).
        Under pool pressure the resume may itself preempt a strictly
        lower-priority running slot."""
        if not self._parked:
            return False
        cand = min(self._parked,
                   key=lambda p: (p.req.eff_priority, p.req.req_id))
        best = self.intake.highest_pending_class()
        if best is not None and best < cand.req.eff_priority:
            if cand.bypassed < self._ov.aging_limit:
                cand.bypassed += 1
                return False
            cand.req.eff_priority = 0   # aged: promoted + immune
        if not self._resume_parked(slot, cand):
            if not self._ov.preemption:
                return False
            victim = self._choose_victim(cand.req.eff_priority)
            if victim is None or not self._preempt_slot(victim):
                return False
            if not self._resume_parked(slot, cand):
                return False
        self._parked.remove(cand)
        return True

    def _discard_parked(self, parked: ParkedSeq, failed: bool = False) -> None:
        """Terminal delivery for a sequence cancelled while parked
        (PREEMPTED -> FREE): partial output from the parked state, cache
        insertions this binding created rolled back, pages freed (the
        swap tombstones are skipped; resident shared pages drop exactly
        this sequence's references).  ``failed``: engine-initiated (lease
        reap / dead engine) rather than a client cancel — counted under
        ``requests_failed``; the caller set the FailedStatus."""
        req = parked.req
        req.tokens_out = parked.outs[:parked.generated].astype(np.int32)
        req.done_t = time.monotonic()
        if self.prefix_cache is not None:
            for key in parked.created_prefixes:
                self.prefix_cache.evict_key(key)
        self.pool.free(req.req_id)
        parked.fsm.transition(states.BUFFER_PREEMPTED, states.BUFFER_FREE)
        if failed and req.fsm.cas(states.REQUEST_RECEIVED,
                                  states.REQUEST_CANCELLED):
            self.stats["requests_failed"] += 1
        else:
            if failed:
                req.fsm.cas(states.REQUEST_VALID, states.REQUEST_CANCELLED)
            self.stats["cancelled"] += 1
        self._respond(req)

    def class_ttft(self) -> Dict[int, Dict[str, float]]:
        """Per-priority-class TTFT summary {class: {n, p50_ms, p99_ms}}
        over retired requests (overload policy active)."""
        out: Dict[int, Dict[str, float]] = {}
        for cls in sorted(self._ttft_by_class):
            xs = sorted(self._ttft_by_class[cls])
            out[cls] = {
                "n": len(xs),
                "p50_ms": 1e3 * xs[len(xs) // 2],
                "p99_ms": 1e3 * xs[min(len(xs) - 1, int(0.99 * len(xs)))],
            }
        return out

    def tick(self) -> Tuple[int, bool]:
        """One engine iteration (micro-batch): abort cancelled slots,
        swap in, harvest + retire, then one *fused block* of K decode
        steps (``slot_fused``) or a single decode step (``slot``, the
        K=1 baseline); ``slot_chunked`` additionally streams one prompt
        chunk per admitting slot inside the same dispatch.  Returns
        (requests retired, did work).

        The whole dispatch runs under the tick watchdog: an exception —
        injected or organic — NEVER propagates out of ``tick()``.
        Transient faults earn ``tick_retries`` whole-tick retries;
        beyond that the bound slots fail with typed terminals and the
        engine keeps serving (``_on_tick_fault``).  When leases are
        armed, silent clients are reaped first."""
        if self.dead is not None:
            return 0, False
        reaped = self._reap_leases() if self.lease_s is not None else False
        self._in_tick = True
        try:
            if self.scheduler in ("slot_chunked", "slot_paged"):
                served, worked = self._tick_chunked()
            elif self.scheduler == "slot_fused":
                served, worked = self._tick_fused()
            else:
                served, worked = self._tick_scalar()
            self._tick_failures = 0
        except Exception as exc:        # noqa: BLE001 — watchdog boundary
            served, worked = self._on_tick_fault(exc)
        finally:
            self._in_tick = False
        # Tick boundary: the one point where host state is consistent
        # (no half-harvested dispatch, no half-claimed admission), so
        # the one point snapshots are taken (DESIGN.md §14).
        self._ticks += 1
        if (self._snap_dir is not None and self.dead is None
                and (self._snap_requested
                     or (self._snap_every is not None
                         and self._ticks % self._snap_every == 0))):
            self._snap_requested = False
            with contextlib.suppress(Exception):
                self.save_snapshot()
        if self.faults is not None:
            self.stats["faults_injected"] = self.faults.n_fired
        return served, worked or reaped

    def _finished(self, req: Request, tok: int, generated: int,
                  pos: int) -> bool:
        """THE per-token retire predicate, shared by every host-side
        harvest (scalar tick, fused prefill harvest, fused block
        harvest).  ``Model.decode_loop`` masks the same three conditions
        on device — keep that pair in lockstep: the fused==scalar
        token-sequence equivalence depends on it."""
        return (tok == req.eos_id or generated >= req.max_tokens
                or pos + 1 >= self.max_len)

    # -- adaptive K (DESIGN.md §6) ---------------------------------------------
    def _choose_k(self, active: List[DecodeSlot]) -> int:
        """Block length for this tick.  K never exceeds the smallest
        remaining *budget* over active slots, so a block ends exactly on
        the step the first budget-bounded sequence finishes — for those,
        retirement and the admission of queued work are never delayed
        past the unfused schedule.  An unpredictable mid-block EOS can
        still retire up to K-1 steps later than the scalar path (the row
        is dead on device but its slot frees at the block boundary) —
        bounded by ``k_max``.  When the pool is under capacity (a FREE
        slot exists), K is further capped at ``k_free`` so a request
        arriving mid-block waits at most ``k_free`` decode steps for
        admission — the bounded-TTFT half of the rule.  A slot whose
        prompt is still *streaming in* (chunked admission) counts the
        same as FREE here: its chunks ride the decode dispatches either
        way, so a long block would only let the rows already decoding
        race ahead solo — throttling to ``k_free`` keeps them co-batched
        with the arrival once its prefill lands (and bounds the
        arrival's time-to-first-block)."""
        k = min(self.k_max,
                min(s.request.max_tokens - s.generated for s in active))
        if len(active) < self.max_batch:
            k = min(k, self.k_free)
        return max(1, k)

    def _loop_fn(self, k: int):
        fn = self._jit_loops.get(k)
        if fn is None:
            model, max_len = self.model, self.max_len
            fn = jax.jit(
                lambda p, c, cur, pos, rem, eos: model.decode_loop(
                    p, c, cur, pos, rem, eos, k=k, max_len=max_len),
                donate_argnums=(1,))
            self._jit_loops[k] = fn
        return fn

    def _chunked_fn(self, k: int):
        """Fused chunk+decode trace for block length ``k`` (``k == 0``:
        chunk-only, used when no row is decoding).  Caches donated: the
        chunk is written in place, never copied."""
        fn = self._jit_chunked.get(k)
        if fn is None:
            model, max_len = self.model, self.max_len
            if k == 0:
                fn = jax.jit(
                    lambda p, c, ch, st, nv: model.prefill_chunk_into(
                        p, c, ch, st, nv),
                    donate_argnums=(1,))
            else:
                fn = jax.jit(
                    lambda p, c, ch, st, nv, cur, pos, rem, eos:
                    model.chunked_block(p, c, ch, st, nv, cur, pos, rem,
                                        eos, k=k, max_len=max_len),
                    donate_argnums=(1,))
            self._jit_chunked[k] = fn
        return fn

    def _reject_streaming(self, slot: DecodeSlot) -> None:
        """Mid-stream pool exhaustion (chunked admission): the whole
        admission rolls back — pages freed, RESERVED slot released, the
        rejected terminal delivered — rather than holding a half-claimed
        reservation while other slots decode."""
        req = slot.request
        self._rollback_created(slot)
        self.pool.free(req.req_id)
        if req.fsm.cas(states.REQUEST_RECEIVED, states.REQUEST_CANCELLED):
            self.stats["rejected"] += 1
        else:
            self.stats["cancelled"] += 1    # client cancel won the race
        req.done_t = time.monotonic()
        req.tokens_out = np.zeros((0,), np.int32)
        self._respond(req)
        self._release_slot(slot)

    def _sweep_in(self) -> bool:
        """Tick head shared by all slot schedulers: (0) abort
        client-cancelled slots — their pages return before admission, so
        a waiting request can take the slot this very tick (for the
        fused scheduler this bounds cancel latency to one block); then
        (1) drain the intake fan-in into ALL free slots (binding them
        RESERVED) before any device work; then (2) for the monolithic-
        prefill schedulers, prefill the newly bound slots.  Draining
        first means a burst of arrivals costs one admission sweep per
        busy period — and under ``slot_chunked`` the reserved slots need
        no dispatch at all here: their first chunks ride the next fused
        block.  With the prefix cache on, the admission loop also
        DEDUPES a burst: a drained request whose whole shareable prefix
        is being prefilled by a slot bound earlier (this sweep or a
        previous one) parks in ``_deferred`` and re-enters a later sweep
        as a cache hit instead of prefilling the same chunks cold.
        Returns True iff anything moved."""
        worked = False
        for slot in self.slots:
            req = slot.request
            if req is not None and req.fsm.state == states.REQUEST_CANCELLED:
                self._abort_slot(slot)
                worked = True
        for parked in list(self._parked):
            if parked.req.fsm.state == states.REQUEST_CANCELLED:
                self._discard_parked(parked)
                self._parked.remove(parked)
                worked = True
        was_idle = not any(s.request is not None for s in self.slots)
        newly: List[DecodeSlot] = []
        intake_dry = False
        for slot in self.slots:
            if slot.request is not None:
                continue
            # Parked sequences compete with intake for every free slot
            # (_try_resume arbitrates by effective class, with aging);
            # a dry intake never blocks later slots from resuming.
            if self._parked and self._try_resume(slot):
                worked = True
                continue
            if intake_dry:
                continue
            req = self._pop_next(slot)
            if req is None:
                intake_dry = True
                continue
            self._bind_slot(slot, req)
            newly.append(slot)
            worked = True
        if newly and was_idle:
            self.stats["batches"] += 1      # new busy period begins
        if self.scheduler not in ("slot_chunked", "slot_paged"):
            for slot in newly:
                self._prefill_slot(slot)
        # Slot-pressure preemption: every slot is busy but more urgent
        # work is waiting — swap the worst strictly-lower-priority
        # decoding slot out (its sequence parks, loses nothing) and
        # bind the urgent arrival in its place.  Only reachable under
        # slot_paged (the policy check pins preemption to it).
        if (self._ov is not None and self._ov.preemption
                and all(s.request is not None for s in self.slots)):
            while True:
                best = self.intake.highest_pending_class()
                if best is None:
                    break
                victim = self._choose_victim(best)
                if victim is None or not self._preempt_slot(victim):
                    break
                req = self._pop_next(victim)
                if req is None:
                    break       # shed/cancel drained it; victim resumes
                self._bind_slot(victim, req)
                worked = True
        return worked

    def _tick_fused(self) -> Tuple[int, bool]:
        """One packet-mode iteration: swap-in and the exact-TTFT harvest
        of prefill tokens stay per-request, then ONE fused device call
        runs K decode steps for the whole slot pool and ONE device→host
        sync harvests the [B, K] token block — per-token host cost
        (jitted-call dispatch + sync + ring push) drops to ≈ 1/K."""
        served = 0
        worked = self._sweep_in()
        # 2) Harvest each fresh admission's prefill token NOW, at K=1 —
        #    TTFT stays exact (measured at real harvest time, never
        #    interpolated); sequences done after one token retire here.
        for slot in self.slots:
            req = slot.request
            if req is None or slot.generated > 0:
                continue
            tok = int(slot.next_tok)
            slot.outs[0] = tok
            slot.generated = 1
            now = time.monotonic()
            req.first_token_t = now
            req.token_ts.append(now)
            self._stream_tokens(req, 0, [tok])
            worked = True
            if self._finished(req, tok, slot.generated, slot.pos):
                self._retire(slot)
                served += 1
        # 3) One fused block over the fixed-shape pool.
        active = [s for s in self.slots if s.request is not None]
        if not active:
            return served, worked
        k = self._choose_k(active)
        rem_v = np.zeros((self.max_batch,), np.int32)
        eos_v = np.full((self.max_batch,), -1, np.int32)
        for s in active:
            rem_v[s.index] = s.request.max_tokens - s.generated
            eos_v[s.index] = s.request.eos_id
        self._fault_raise("engine.dispatch")    # pre-device: retry is safe
        t0 = time.monotonic()
        # K=1 rides the same donated decode_loop trace (a scan of one
        # decode_step): uniform harvest below, and the persistent cache
        # is updated in place for every block size, never copied.
        blk_dev, self._caches = self._loop_fn(k)(
            self.params, self._caches, jnp.asarray(self._cur),
            jnp.asarray(self._pos), jnp.asarray(rem_v),
            jnp.asarray(eos_v))
        blk = np.asarray(blk_dev).astype(np.int64)
        self.stats["host_syncs"] += 1   # the ONE sync for the whole block
        t1 = time.monotonic()
        self._fault_raise("engine.sync", retryable=False)
        served += self._harvest_block(active, blk, k, t0, t1)
        return served, True

    def _harvest_block(self, active: List[DecodeSlot], blk: np.ndarray,
                       k: int, t0: float, t1: float,
                       joined: Tuple[DecodeSlot, ...] = ()) -> int:
        """Harvest one fetched [B, K] token block (shared by the fused
        and chunked schedulers): valid tokens form a per-row prefix
        (device masking stops emission at EOS/budget/max_len).  Rows in
        ``joined`` also produced their prefill token in this same
        dispatch, so their k+1 tokens share the interpolation window.
        Returns requests retired."""
        served = 0
        for s in active:
            req = s.request
            s.fresh_resume = False      # a full block decoded: fair game
            row = blk[s.index]
            n_valid = int((row >= 0).sum())
            first_pos = s.generated
            nb = 1 if s in joined else 0
            for j in range(n_valid):
                s.outs[s.generated] = row[j]
                s.generated += 1
                # Per-token timestamps interpolated within the block:
                # the block produced its tokens at a uniform device
                # cadence between t0 and t1.
                req.token_ts.append(
                    t0 + (j + 1 + nb) * (t1 - t0) / (k + nb))
            s.pos += n_valid
            self._pos[s.index] = s.pos
            self._cur[s.index] = int(row[n_valid - 1])
            # ONE page-accounting call per block (note_tokens is
            # idempotent growth inside the admission reservation).
            self.pool.note_tokens(req.req_id, s.pos)
            if s.pending_prefix:
                # Decode growth can complete a prefix's trailing page
                # (bucket < page_size): publish entries as they ripen.
                self._maybe_insert_prefixes(s)
            # ONE stream-ring burst per block per request.
            self._stream_tokens(req, first_pos, row[:n_valid])
            self.stats["slot_busy_steps"] += n_valid
            last = int(row[n_valid - 1])
            if n_valid < k or self._finished(req, last, s.generated, s.pos):
                self._retire(s)
                served += 1
        self.stats["decode_steps"] += k
        self.stats["fused_blocks"] += 1
        return served

    def _tick_chunked(self) -> Tuple[int, bool]:
        """One chunked-admission iteration (DESIGN.md §9): every slot
        whose prompt is still streaming contributes its next fixed-shape
        chunk, every decoding slot its next K steps, and BOTH ride ONE
        jitted dispatch and ONE host fetch — admission costs zero
        dedicated syncs, zero cache-copy dispatches, and stalls active
        decode by zero steps (the monolithic path stalls every active
        slot once per admission and pays a sync + copy dispatch).

        ``slot_paged`` shares this tick verbatim — the only difference
        is the cache operand (``_take_caches``): pool page arrays + the
        per-slot block table instead of the dense batch cache, so the
        same dispatch discipline gains length-proportional residency
        and zero-copy swap-in (DESIGN.md §10).  Token sequences are
        byte-identical across slot_fused/slot_chunked/slot_paged."""
        served = 0
        worked = self._sweep_in()
        B, C = self.max_batch, self.chunk_tokens
        # 2) Assemble this dispatch's chunk rows.  The page reservation
        #    is extended to cover exactly the positions this chunk will
        #    materialize (plus the decode budget with the final chunk)
        #    BEFORE any device work, so pool exhaustion aborts the
        #    admission cleanly pre-dispatch.
        chunk = np.zeros((B, C), np.int32)
        start_v = np.zeros((B,), np.int32)
        nval_v = np.zeros((B,), np.int32)
        chunks: List[Tuple[DecodeSlot, int, bool]] = []
        for s in self.slots:
            if s.request is None or s.generated > 0:
                continue
            req = s.request
            n_rem = len(s.prompt) - s.prefill_pos
            v = min(C, n_rem)
            final = v == n_rem
            need = (len(s.prompt) + req.max_tokens if final
                    else s.prefill_pos + v)
            if not self._extend_with_preemption(s, need):
                self._reject_streaming(s)
                worked = True
                continue
            # Copy-on-write gate (DESIGN.md §11): this chunk writes
            # positions [prefill_pos, need) — any page there another
            # holder can read (a shared prefix hit's trailing partial
            # page) is repointed to a private copy BEFORE the block
            # table is assembled, so the dispatch never scatters into a
            # page someone else attends.  A final chunk's range covers
            # the decode budget too: the joiner's on-device first steps
            # write there in this same dispatch.
            if (self.prefix_cache is not None and self.pool.ensure_private(
                    req.req_id, s.prefill_pos, need) != POOL_OK):
                self._reject_streaming(s)
                worked = True
                continue
            if (self.faults is not None
                    and self.faults.fire("pool.page_write") is not None):
                # Poisoned write: the pages this chunk would have
                # scattered into are declared corrupted.  Quarantine
                # pins them BEFORE _fail_slot frees the sequence (the
                # pin is the extra refcount that survives the free), so
                # they never re-enter circulation.
                qp = self.pool.quarantine_range(req.req_id,
                                                s.prefill_pos, need)
                self.stats["pages_quarantined"] += len(qp)
                self._fail_slot(s, "poisoned page write "
                                   f"({len(qp)} pages quarantined)")
                worked = True
                continue
            chunk[s.index, :v] = s.prompt[s.prefill_pos:s.prefill_pos + v]
            start_v[s.index] = s.prefill_pos
            nval_v[s.index] = v
            chunks.append((s, v, final))
        active = [s for s in self.slots
                  if s.request is not None and s.generated > 0]
        if self.prefix_cache is not None and active:
            # Decode rows write [pos, pos + k): structurally these pages
            # are already private (sharing stops at the prompt prefix
            # and the final chunk privatized its tail), but the fused
            # block must never scatter into a shared page, so the same
            # gate runs here — a pure host-side refcount scan when
            # nothing is shared.
            still: List[DecodeSlot] = []
            for s in active:
                if self.pool.ensure_private(
                        s.request.req_id, s.pos,
                        s.pos + self.k_max) == POOL_OK:
                    still.append(s)
                else:           # CoW under total exhaustion: cancel whole
                    s.request.fsm.cas(states.REQUEST_RECEIVED,
                                      states.REQUEST_CANCELLED)
                    self._abort_slot(s)
                    worked = True
            active = still
        if not chunks and not active:
            return served, worked
        caches = self._take_caches()
        pos_v = self._pos.copy()
        for s, v, _ in chunks:
            # Streaming rows pass their POST-chunk extent: the decode
            # scan's idle-row junk write lands on the next *unwritten*
            # slot, overwritten by the next chunk (or the row's own
            # first decode step) before it is ever attended.
            pos_v[s.index] = s.prefill_pos + v
        rem_v = np.zeros((B,), np.int32)
        eos_v = np.full((B,), -1, np.int32)
        for s in active:
            rem_v[s.index] = s.request.max_tokens - s.generated
            eos_v[s.index] = s.request.eos_id
        # Rows whose FINAL chunk rides this dispatch JOIN the decode
        # block immediately (Model.chunked_block feeds them their
        # on-device prefill token): rem is the budget minus that first
        # token, so a max_tokens=1 row correctly stays out of the scan.
        for s, v, final in chunks:
            if final:
                rem_v[s.index] = s.request.max_tokens - 1
                eos_v[s.index] = s.request.eos_id
        # Adaptive K over everything that will decode this dispatch
        # (continuing rows AND joiners); capped at k_free while a slot
        # is FREE or a prompt is still mid-stream, so arrivals and
        # later chunks never wait behind a long solo block.
        budgets = ([s.request.max_tokens - s.generated for s in active]
                   + [s.request.max_tokens - 1 for s, _, final in chunks
                      if final and s.request.max_tokens > 1])
        if budgets:
            k = min(self.k_max, min(budgets))
            if (any(s.request is None for s in self.slots)
                    or any(not final for _, _, final in chunks)):
                k = min(k, self.k_free)
            k = max(1, k)
        else:
            k = 0
        # 3) ONE dispatch: chunk and K-step block fused when both exist.
        # Dispatch probe sits here — after ALL host bookkeeping, before
        # any device work — so a retried tick reassembles idempotently
        # (extend claims 0 new pages, ensure_private finds nothing
        # shared) and redispatches the identical work.
        self._fault_raise("engine.dispatch")
        t0 = time.monotonic()
        tok_pf = blk = None
        if chunks and k:
            tok_dev, blk_dev, caches = self._chunked_fn(k)(
                self.params, caches, jnp.asarray(chunk),
                jnp.asarray(start_v), jnp.asarray(nval_v),
                jnp.asarray(self._cur), jnp.asarray(pos_v),
                jnp.asarray(rem_v), jnp.asarray(eos_v))
            tok_pf = np.asarray(tok_dev)
            blk = np.asarray(blk_dev).astype(np.int64)
        elif chunks:
            tok_dev, caches = self._chunked_fn(0)(
                self.params, caches, jnp.asarray(chunk),
                jnp.asarray(start_v), jnp.asarray(nval_v))
            tok_pf = np.asarray(tok_dev)
        else:
            blk_dev, caches = self._loop_fn(k)(
                self.params, caches, jnp.asarray(self._cur),
                jnp.asarray(pos_v), jnp.asarray(rem_v),
                jnp.asarray(eos_v))
            blk = np.asarray(blk_dev).astype(np.int64)
        self._give_caches(caches)
        self.stats["host_syncs"] += 1   # ONE fetch covers chunk AND block
        if chunks:
            self.stats["prefills"] += 1
            self.stats["prefill_dispatches"] += 1
        t1 = time.monotonic()
        # Sync "timeout": the device advanced but the host never
        # harvested — a retry would re-decode past the recorded state,
        # so this one is non-retryable: the watchdog fails the slots.
        self._fault_raise("engine.sync", retryable=False)
        # 4) Harvest chunks.  A final chunk delivers the prefill's first
        #    token straight from the regular block fetch (exact TTFT, no
        #    dedicated host sync), flips the slot ALLOCATED, and — when
        #    the dispatch carried a decode block — the row's first K
        #    decode tokens are already in it (it joined on device).
        joined: List[DecodeSlot] = []
        for s, v, final in chunks:
            req = s.request
            s.prefill_pos += v
            self.stats["prefill_chunks"] += 1
            self._maybe_insert_prefixes(s)
            if not final:
                self.pool.note_tokens(req.req_id, s.prefill_pos)
                continue
            tok = int(tok_pf[s.index])
            s.fsm.transition(states.BUFFER_RESERVED,
                             states.BUFFER_ALLOCATED)
            s.pos = s.prefill_pos
            self._pos[s.index] = s.pos
            self._cur[s.index] = tok
            s.outs[0] = tok
            s.generated = 1
            # The first token came back with the block fetch: when the
            # dispatch also decoded (k > 0) its timestamp is the first
            # point of the dispatch's interpolation window, keeping
            # token_ts monotone with the decode tokens that followed it
            # on device; a chunk-only dispatch stamps real harvest time.
            ts0 = (t0 + (t1 - t0) / (k + 1)) if k else time.monotonic()
            req.first_token_t = ts0
            req.token_ts.append(ts0)
            self.pool.note_tokens(req.req_id, s.pos)
            self._stream_tokens(req, 0, [tok])
            if self._finished(req, tok, s.generated, s.pos):
                # Done at the prefill token: the device's initial
                # liveness mask kept this row out of the block.
                self._retire(s)
                served += 1
            elif k:
                joined.append(s)
        # 5) Harvest the decode block (continuing rows + joiners).
        if k:
            served += self._harvest_block(active + joined, blk, k, t0, t1,
                                          joined=tuple(joined))
        return served, True

    def _tick_scalar(self) -> Tuple[int, bool]:
        """The unfused baseline (scheduler="slot"): one decode step and
        one host sync per tick — the scalar-channel side of the paper's
        packet-vs-scalar comparison, kept for A/B benchmarking."""
        served = 0
        worked = self._sweep_in()       # 0-1) aborts + admissions
        # 2) Harvest the token each active slot produced (prefill or the
        #    previous decode step); stream it to the client; retire
        #    finished sequences NOW.
        for slot in self.slots:
            req = slot.request
            if req is None:
                continue
            slot.outs[slot.generated] = slot.next_tok
            slot.generated += 1
            now = time.monotonic()
            if slot.generated == 1:
                req.first_token_t = now     # TTFT measurement point
            req.token_ts.append(now)
            self._stream_tokens(req, slot.generated - 1,
                                [int(slot.next_tok)])
            worked = True
            if self._finished(req, int(slot.next_tok), slot.generated,
                              slot.pos):
                self._retire(slot)
                served += 1
        # 3) One decode step over the fixed-shape batch; idle rows are
        #    masked by their own per-row position (layers.attention).
        active = [s for s in self.slots if s.request is not None]
        if active:
            self._fault_raise("engine.dispatch")
            cur, self._caches = self._jit_decode(
                self.params, self._caches, jnp.asarray(self._cur)[:, None],
                jnp.asarray(self._pos))
            cur = np.asarray(cur)
            self.stats["host_syncs"] += 1   # one sync per decode step
            self._fault_raise("engine.sync", retryable=False)
            for s in active:
                s.next_tok = int(cur[s.index])
                s.pos += 1
                self._pos[s.index] = s.pos
                self._cur[s.index] = s.next_tok
                self.pool.note_tokens(s.request.req_id, s.pos)
            self.stats["decode_steps"] += 1
            self.stats["slot_busy_steps"] += len(active)
            worked = True
        return served, worked

    def occupancy(self) -> float:
        """Mean fraction of decode slots doing useful work per step."""
        steps = self.stats["decode_steps"]
        return (self.stats["slot_busy_steps"] / (steps * self.max_batch)
                if steps else 0.0)

    # ===========================================================================
    # Wave scheduler (baseline): batch-level waves, kept for A/B benchmarks.
    # ===========================================================================
    def _take_batch(self, timeout_s: float = 0.05) -> List[Request]:
        """Greedy batcher: first request blocks briefly, rest drained free."""
        batch: List[Request] = []
        deadline = time.monotonic() + timeout_s
        backoff = transport.Backoff()
        while len(batch) < self.max_batch:
            status, req = self.intake.try_recv()
            if status == nbb.OK:
                backoff.reset()
                # admission control: KV pages for prompt + generation
                need = len(req.prompt) + req.max_tokens
                if self.pool.try_admit(req.req_id, need) != POOL_OK:
                    self._reject(req)
                    continue
                if not req.fsm.cas(states.REQUEST_VALID,
                                   states.REQUEST_RECEIVED):
                    self.pool.free(req.req_id)   # cancelled while queued
                    self._finish_cancelled(req)
                    continue
                batch.append(req)
            elif batch or time.monotonic() > deadline:
                break
            else:
                # Table-1 discipline: spin on transient, then yield, then
                # exponential sleep — not a fixed 1 ms busy-wait.
                backoff.wait(status)
        return batch

    def _run_batch(self, batch: List[Request]) -> None:
        B = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        tok, caches = self._jit_prefill(self.params, jnp.asarray(toks))
        self.stats["prefills"] += 1
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_chunks"] += B   # one whole-prompt chunk each

        max_new = max(r.max_tokens for r in batch)
        outs = np.full((B, max_new), -1, np.int64)
        done = np.zeros((B,), bool)
        cur = tok
        for step in range(max_new):
            outs[~done, step] = np.asarray(cur)[~done]
            self.stats["host_syncs"] += 1
            for i, r in enumerate(batch):
                if not done[i] and (outs[i, step] == r.eos_id
                                    or step + 1 >= r.max_tokens):
                    done[i] = True
            if done.all() or plen + step + 1 >= self.max_len:
                break
            cur, caches = self._jit_decode(self.params, caches, cur[:, None],
                                           jnp.int32(plen + step))
            self.stats["decode_steps"] += 1

        for i, r in enumerate(batch):
            got = outs[i][outs[i] >= 0].astype(np.int32)
            r.tokens_out = got
            r.done_t = time.monotonic()
            # No streaming in the wave baseline: the first token reaches
            # the client with the whole response (this is what the TTFT
            # benchmark measures against).
            r.first_token_t = r.done_t
            if r.fsm.cas(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED):
                self.stats["served"] += 1
            else:
                self.stats["cancelled"] += 1
            self.pool.free(r.req_id)
            self._respond(r)
        self.stats["batches"] += 1

    # -- engine loop --------------------------------------------------------------
    def step(self) -> int:
        """Drain everything currently runnable; returns requests served.

        Wave scheduler: one fused batch.  Slot schedulers: tick until
        the slot pool and intake are both idle (each tick is one decode
        block — a single step for "slot", K steps for "slot_fused" — so
        admissions interleave with decode)."""
        if self.scheduler == "wave":
            batch = self._take_batch()
            if not batch:
                return 0
            self._run_batch(batch)
            return len(batch)
        total = 0
        while True:
            served, worked = self.tick()
            total += served
            if not worked:
                return total

    # -- crash recovery (DESIGN.md §14) -----------------------------------------
    def request_snapshot(self) -> None:
        """Ask the batcher thread to snapshot at its next tick boundary
        (safe from any thread, including a signal handler: one boolean
        store)."""
        self._snap_requested = True

    def _config_fingerprint(self) -> Dict[str, object]:
        """The shape contract a snapshot restores onto: same model, same
        slot/pool geometry, same scheduler.  Asserted at restore — a
        snapshot is an image of THIS engine shape, not a migration
        format.  (Byte-identical resumption additionally assumes the
        same params; those are not fingerprinted — checksumming weights
        per snapshot would dwarf the snapshot itself.)"""
        return {
            "arch": self.model.cfg.name,
            "vocab": self.model.cfg.vocab_size,
            "max_batch": self.max_batch, "max_len": self.max_len,
            "page_size": self.pool.page_size,
            "pool_pages": self.pool.n_pages,
            "chunk_tokens": self.chunk_tokens,
            "k_max": self.k_max, "k_free": self.k_free,
            "scheduler": self.scheduler,
            "n_clients": len(self._sessions),
            "prefix_cache": self.prefix_cache is not None,
        }

    def _journal_bind(self, req: Request) -> None:
        """WAL append at BIND: prompt + decode parameters are the whole
        replay story — greedy decode is deterministic, so re-binding the
        same record yields the same tokens.  The ``journal.append``
        fault site models a lost record: the request still serves in
        this life, but cannot be replayed after a crash (its handle
        finalizes as "lost across restart" on re-bind)."""
        if (self.faults is not None
                and self.faults.fire("journal.append") is not None):
            return                      # injected: record lost
        self._journal.append({
            "req_id": req.req_id, "client_id": req.client_id,
            "prompt": np.asarray(req.prompt, np.int32),
            "max_tokens": req.max_tokens, "eos_id": req.eos_id,
            "priority": req.priority, "slo_s": req.slo_s,
        })

    def snapshot(self) -> "snapshot_mod.EngineSnapshot":
        """Capture the crash-consistent engine image at the current tick
        boundary (batcher thread only; one host sync for the page
        gather).  Shared pages are captured once however many block
        tables point at them; prefix-cache entries are recorded by their
        chain keys and page lists — restore re-claims the same physical
        pages, it never copies per sequence."""
        if self.scheduler != "slot_paged":
            raise SnapshotError(
                f"snapshot() needs scheduler='slot_paged', "
                f"not {self.scheduler!r}")
        extra = (self.prefix_cache.resident_pages()
                 if self.prefix_cache is not None else ())
        pool_state = self.pool.snapshot_state(extra_pages=extra)
        self.stats["host_syncs"] += 1
        prefix_entries = []
        if self.prefix_cache is not None:
            for e in sorted(self.prefix_cache._entries.values(),
                            key=lambda e: e.tick):
                prefix_entries.append((e.key, e.n_tokens, list(e.pages)))
        slots = []
        for s in self.slots:
            if s.request is None:
                continue
            slots.append(snapshot_mod.SlotImage(
                index=s.index, fsm=s.fsm, request=s.request,
                cur_token=int(self._cur[s.index]), pos=s.pos,
                generated=s.generated, outs=s.outs, prompt=s.prompt,
                prefill_pos=s.prefill_pos, next_tok=s.next_tok,
                chunk_hashes=(list(s.chunk_hashes)
                              if s.chunk_hashes is not None else None),
                pending_prefix=list(s.pending_prefix),
                created_prefixes=list(s.created_prefixes),
                fresh_resume=s.fresh_resume))
        # Peek (never consume) the in-flight rings: intake-resident
        # submissions and undelivered terminals are exactly what a crash
        # at this boundary would strand.
        queued: List[Request] = []
        for c in range(len(self._sessions)):
            for ring in self._client_rings(c):
                queued.extend(snapshot_mod.peek_ring(ring))
        undelivered: Dict[int, List[Request]] = {}
        for c in range(len(self._sessions)):
            items = snapshot_mod.peek_ring(self._raw_ring(self.responses[c]))
            if items:
                undelivered[c] = list(items)
        return snapshot_mod.EngineSnapshot(
            config=self._config_fingerprint(),
            journal_seq=(self._journal.seq
                         if self._journal is not None else 0),
            next_req_id=next(self._id),     # burns one id: ids may skip,
            pool=pool_state,                # never collide across a restore
            prefix_entries=prefix_entries,
            slots=slots, cur=self._cur.copy(), pos=self._pos.copy(),
            parked=list(self._parked),
            deferred=[(r, list(k)) for r, k in self._deferred],
            queued=queued, undelivered=undelivered,
            stats=dict(self.stats))

    def save_snapshot(self) -> Optional[str]:
        """Capture + write to ``snapshot_dir``.  Returns the path, or
        None when snapshots are disarmed or the write was torn by an
        injected ``snapshot.write`` fault (the previous good snapshot
        survives either way — tmp + checksum + atomic rename)."""
        if self._snap_dir is None:
            return None
        snap = self.snapshot()
        path = snapshot_mod.write_snapshot(snap, self._snap_dir,
                                           faults=self.faults)
        self.stats["snapshots"] += 1
        if path is not None:
            self.stats["snapshot_bytes"] = os.path.getsize(path)
        return path

    def _reset_runtime(self) -> None:
        """Empty pre-admission state on the existing engine object:
        fresh rings (sessions survive — their handles re-bind), free
        slots, no parked/deferred/in-flight bookkeeping.  The pool and
        prefix cache are NOT reset here; restore_state rebuilds them
        wholesale (callers that give up entirely reset the pool too)."""
        n_clients = len(self._sessions)
        self.intake = (PriorityIntake(n_clients, self._ov,
                                      self._intake_depth)
                       if self._ov is not None else
                       MpscQueue(n_clients,
                                 capacity_per_producer=self._intake_depth))
        self.responses = [SpscQueue(self._intake_depth)
                          for _ in range(n_clients)]
        self.streams = [SpscQueue(self._stream_depth)
                        for _ in range(n_clients)]
        if self.faults is not None:
            self.streams = [
                transport.FaultyTransport(r, self.faults, f"stream{c}")
                for c, r in enumerate(self.streams)]
            self.responses = [
                transport.FaultyTransport(r, self.faults, f"responses{c}")
                for c, r in enumerate(self.responses)]
        self.slots = [DecodeSlot(i) for i in range(self.max_batch)]
        self._cur[:] = 0
        self._pos[:] = 0
        self._caches = None
        self._parked = []
        self._deferred = []
        self._inflight = {}
        self._pending_bind = {}
        self._restore_queue.clear()
        self._restored_reqs = {}
        if self.prefix_cache is not None:
            # Entries drop without decref: the pool is rebuilt (or
            # reset) wholesale right after, counts and all.
            self.prefix_cache._entries.clear()
        self.dead = None
        self._stop.clear()
        self._tick_failures = 0
        self._reaped = set()
        self._ticks = 0
        self._snap_requested = False

    def restore(self, snap: Union["snapshot_mod.EngineSnapshot", str,
                                  os.PathLike]) -> Dict[str, object]:
        """Reconstruct the engine from a snapshot (object or file path)
        and resume decode mid-stream: pool pages re-claimed at their
        exact physical ids and refcounts, block tables verbatim, prefix
        cache re-adopted by key, bound/parked slots with their Figure-4
        FSMs and decode cursors, stranded intake re-queued, undelivered
        terminals re-sent, and WAL records past the snapshot's
        high-water mark replayed as fresh submissions (deterministic:
        greedy decode).  All-or-nothing: any failure resets the engine
        empty and raises :class:`SnapshotError`; an injected
        ``snapshot.restore`` fault aborts before any mutation."""
        if isinstance(snap, (str, os.PathLike)):
            snap = snapshot_mod.read_snapshot(os.fspath(snap))
        self._fault_raise("snapshot.restore")
        fp = self._config_fingerprint()
        if snap.config != fp:
            diff = {k: (snap.config.get(k), fp.get(k))
                    for k in set(snap.config) | set(fp)
                    if snap.config.get(k) != fp.get(k)}
            raise SnapshotError(f"config mismatch, cannot restore: {diff}")
        try:
            with self._paused_plan():
                self._reset_runtime()
                self.pool.restore_state(snap.pool)
                if self.prefix_cache is not None:
                    for key, n_tok, pages in snap.prefix_entries:
                        self.prefix_cache._entries[key] = PrefixEntry(
                            key, n_tok, list(pages),
                            next(self.prefix_cache._clock))
                self._cur[:] = snap.cur
                self._pos[:] = snap.pos
                for img in snap.slots:
                    s = self.slots[img.index]
                    s.fsm = img.fsm
                    s.request = img.request
                    s.next_tok = img.next_tok
                    s.pos = img.pos
                    s.generated = img.generated
                    s.outs = img.outs
                    s.prompt = img.prompt
                    s.prefill_pos = img.prefill_pos
                    s.chunk_hashes = img.chunk_hashes
                    s.pending_prefix = list(img.pending_prefix)
                    s.created_prefixes = list(img.created_prefixes)
                    s.fresh_resume = img.fresh_resume
                    if img.chunk_hashes:
                        for h in img.chunk_hashes:
                            self._inflight[h] = self._inflight.get(h, 0) + 1
                    self._restored_reqs[img.request.req_id] = img.request
                self._parked = list(snap.parked)
                for p in self._parked:
                    self._restored_reqs[p.req.req_id] = p.req
                self._deferred = [(r, list(k)) for r, k in snap.deferred]
                for req, _ in self._deferred:
                    self._restored_reqs[req.req_id] = req
                now = time.monotonic()
                for req in snap.queued:
                    # The previous life's monotonic clock means nothing
                    # here; the queue wait restarts (SLO sheds must not
                    # fire on a stale cross-process timestamp).
                    req.submit_t = now
                    self._restore_queue.append(req)
                    self._restored_reqs[req.req_id] = req
                replayed = 0
                if self._journal is not None:
                    for rec in self._journal.records[snap.journal_seq:]:
                        if rec["req_id"] in self._restored_reqs:
                            continue    # bound from the snapshot's own
                        req = Request(  # queue after capture: not lost
                            rec["req_id"], rec["client_id"],
                            np.asarray(rec["prompt"], np.int32),
                            rec["max_tokens"], rec["eos_id"],
                            submit_t=now)
                        req.priority = req.eff_priority = rec["priority"]
                        req.slo_s = rec["slo_s"]
                        req.fsm.transition(states.REQUEST_FREE,
                                           states.REQUEST_VALID)
                        self._restore_queue.append(req)
                        self._restored_reqs[req.req_id] = req
                        replayed += 1
                redelivered = 0
                for c, reqs in snap.undelivered.items():
                    for req in reqs:
                        self._restored_reqs[req.req_id] = req
                        self._respond(req)
                        redelivered += 1
                max_seen = max(self._restored_reqs, default=-1)
                self._id = itertools.count(
                    max(snap.next_req_id, max_seen + 1))
        except SnapshotError:
            raise
        except Exception as exc:
            with contextlib.suppress(Exception):
                self._reset_runtime()
                self.pool.reset()
            raise SnapshotError(f"restore failed mid-rebuild: {exc!r}")
        self.stats = dict(snap.stats)
        self.stats["restores"] += 1
        self.stats["replayed_requests"] += replayed
        self.stats["restarts"] = self._restart_count
        self.restore_report = {
            "resumed": len(snap.slots) + len(snap.parked)
                       + len(snap.deferred) + len(snap.queued),
            "replayed": replayed,
            "redelivered": redelivered,
            "failed": 0,
        }
        return self.restore_report

    def restore_latest(self, retries: int = 8) -> Optional[Dict[str, object]]:
        """Restore from the newest *valid* snapshot in ``snapshot_dir``,
        retrying through injected ``snapshot.restore`` faults (finite
        plans go quiet).  None when no usable snapshot exists or every
        retry failed — the engine is then reset empty (pool included)
        so re-bound handles fail typed instead of hanging."""
        if self._snap_dir is None:
            return None
        for _ in range(max(1, retries)):
            snap, path = snapshot_mod.load_latest(self._snap_dir)
            if snap is None:
                return None
            try:
                report = self.restore(snap)
                report["path"] = path
                return report
            except (SnapshotError, faults_mod.InjectedFault):
                continue
        with contextlib.suppress(Exception):
            self._reset_runtime()
            self.pool.reset()
        return None

    def _restart_from_crash(self, exc: Exception) -> bool:
        """The in-process relaunch (``serve_forever(restart=True)``):
        attempt a final boundary snapshot, restore from the newest good
        one, re-bind every live session handle.  False => no usable
        snapshot or the restart budget is spent (the caller dies the
        PR-8 way: typed terminals for everything)."""
        if self._restart_count >= 5:
            return False                # a deterministic crash loop must
        if not self._in_tick:           # not restart forever
            with contextlib.suppress(Exception):
                self.save_snapshot()
        report = self.restore_latest()
        if report is None:
            return False
        self._restart_count += 1
        self.stats["restarts"] = self._restart_count
        for sess in self._sessions:
            sess._rebind_restored()
        return True

    def serve_forever(self, restart: bool = False) -> None:
        """The engine loop, with a last-resort boundary: slot-scheduler
        ticks never raise (the watchdog), but if the loop itself somehow
        crashes — wave scheduler, a bug in recovery — the engine dies
        CLEANLY: every outstanding request resolves with a typed
        FailedStatus instead of clients hanging on rings nobody will
        ever fill again.

        With ``restart=True`` (and ``snapshot_dir`` armed) a loop crash
        relaunches instead: final snapshot attempt, restore from the
        newest good snapshot, handles re-bound, loop resumed — process
        death becomes a recoverable event (DESIGN.md §14).  On a CLEAN
        stop the final state is snapshotted so a later process can
        ``--restore`` it."""
        backoff = transport.Backoff()
        while not self._stop.is_set():
            try:
                if self.scheduler == "wave":
                    worked = self.step() > 0
                else:
                    _, worked = self.tick()
            except Exception as exc:    # noqa: BLE001 — death boundary
                if (not restart or self._snap_dir is None
                        or not self._restart_from_crash(exc)):
                    self._die(f"engine loop crashed: {exc!r}")
                    return
                backoff.reset()
                continue
            if worked:
                backoff.reset()
            else:
                backoff.wait(nbb.BUFFER_EMPTY)
        if self._snap_dir is not None and self.dead is None:
            # Graceful shutdown: park the final consistent state for a
            # later --restore.  With work still in flight, _die does the
            # parking — its last-gasp snapshot captures the live slots /
            # parked / queued requests FIRST, then resolves every handle
            # with a typed terminal, so no client hangs on a stopped
            # engine (the next process resumes them from the snapshot).
            pending = (any(s.request is not None for s in self.slots)
                       or bool(self._parked) or bool(self._deferred)
                       or bool(self._restore_queue))
            if not pending:
                for c in range(len(self._sessions)):
                    if any(snapshot_mod.peek_ring(r)
                           for r in self._client_rings(c)):
                        pending = True
                        break
            if pending:
                self._die("engine stopped; state snapshotted for restore")
            else:
                with contextlib.suppress(Exception):
                    self.save_snapshot()

    def start(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def stop(self) -> None:
        self._stop.set()

    # -- client-side receive -----------------------------------------------------
    def get_response(self, client_id: int, timeout_s: float = 30.0
                     ) -> Union[Request, TimeoutStatus, "FailedStatus"]:
        """Next terminal Request for this client (legacy whole-response
        surface): a wrapper over the session's pump.  On timeout returns
        a falsy :class:`TimeoutStatus` rather than raising or returning a
        bare None, so callers can branch on the typed status; on a dead
        engine, a falsy :class:`FailedStatus` immediately instead of
        burning the whole timeout on rings nobody fills."""
        return self._sessions[client_id].next_response(timeout_s)
