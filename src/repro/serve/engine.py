"""Serving engine: lock-free request intake, batched prefill/decode.

MCAPI topology, lock-free end to end (paper Figures 1-4 without the red
lock):

  client threads --SPSC NBB rings--> batcher --> prefill+decode -->
      --per-client SPSC response rings--> clients

  * intake      — each client owns a private SPSC ring of an MpscQueue;
                  submission is InsertItem with Table-1 status codes.
  * lifecycle   — every request carries a CAS FSM cell (Figure 3):
                  FREE->VALID on submit, ->RECEIVED when batched,
                  ->COMPLETED on finish, ->CANCELLED on reject;
                  illegal transitions throw, catching scheduler bugs.
  * KV memory   — admission claims pages from the lock-free bitset pool
                  (kv_cache.py); a full pool *rejects* (BUFFER_FULL
                  semantics) instead of blocking the batcher.
  * decode      — greedy, batched; a `done` mask retires sequences at
                  EOS/max_tokens; the round ends when all retire
                  (batch-level continuous batching — the next wave is
                  admitted immediately; iteration-level slot swap is
                  future work, noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nbb, states
from repro.core.host_queue import MpscQueue, SpscQueue
from repro.serve.kv_cache import OK as POOL_OK
from repro.serve.kv_cache import PagedKVPool


@dataclasses.dataclass
class Request:
    req_id: int
    client_id: int
    prompt: np.ndarray                  # [T] int32
    max_tokens: int = 16
    eos_id: int = -1                    # -1: never
    fsm: states.StateCell = dataclasses.field(
        default_factory=lambda: states.request_cell())
    tokens_out: Optional[np.ndarray] = None
    submit_t: float = 0.0
    done_t: float = 0.0


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 128, n_clients: int = 2,
                 pool_pages: int = 64, page_size: int = 16,
                 intake_depth: int = 32):
        self.model, self.params = model, params
        self.max_batch, self.max_len = max_batch, max_len
        cfg = model.cfg
        self.intake = MpscQueue(n_clients, capacity_per_producer=intake_depth)
        self.responses = [SpscQueue(intake_depth) for _ in range(n_clients)]
        self.pool = PagedKVPool(
            pool_pages, page_size, n_layers=cfg.num_layers,
            kv_heads=max(cfg.num_kv_heads, 1), head_dim=cfg.head_dim_ or 1,
            dtype=cfg.compute_dtype)
        self._id = itertools.count()
        self._stop = threading.Event()
        self._jit_decode = jax.jit(model.decode_step)
        self._prefill_cache: Dict[Any, Any] = {}
        self.stats = {"served": 0, "rejected": 0, "batches": 0,
                      "decode_steps": 0}

    # -- client API (any thread) ------------------------------------------------
    def submit(self, client_id: int, prompt: np.ndarray,
               max_tokens: int = 16, eos_id: int = -1) -> Optional[Request]:
        """Non-blocking submit.  None => intake ring full (caller retries)."""
        req = Request(next(self._id), client_id, np.asarray(prompt, np.int32),
                      max_tokens, eos_id, submit_t=time.monotonic())
        req.fsm.transition(states.REQUEST_FREE, states.REQUEST_VALID)
        status = self.intake.insert_item(client_id, req)
        if status != nbb.OK:
            req.fsm.transition(states.REQUEST_VALID, states.REQUEST_CANCELLED)
            return None
        return req

    # -- engine loop --------------------------------------------------------------
    def _take_batch(self, timeout_s: float = 0.05) -> List[Request]:
        """Greedy batcher: first request blocks briefly, rest drained free."""
        batch: List[Request] = []
        deadline = time.monotonic() + timeout_s
        while len(batch) < self.max_batch:
            status, req = self.intake.read_item()
            if status == nbb.OK:
                # admission control: KV pages for prompt + generation
                need = len(req.prompt) + req.max_tokens
                if self.pool.try_admit(req.req_id, need) != POOL_OK:
                    req.fsm.transition(states.REQUEST_VALID,
                                       states.REQUEST_CANCELLED)
                    self.stats["rejected"] += 1
                    self._respond(req)
                    continue
                req.fsm.transition(states.REQUEST_VALID,
                                   states.REQUEST_RECEIVED)
                batch.append(req)
            elif batch or time.monotonic() > deadline:
                break
            else:
                time.sleep(0.001)
        return batch

    def _prefill_fn(self, prompt_len: int):
        key = prompt_len
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, t: self.model.prefill(p, t, self.max_len))
        return self._prefill_cache[key]

    def _run_batch(self, batch: List[Request]) -> None:
        B = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        tok, caches = self._prefill_fn(plen)(self.params, jnp.asarray(toks))

        max_new = max(r.max_tokens for r in batch)
        outs = np.full((B, max_new), -1, np.int64)
        done = np.zeros((B,), bool)
        cur = tok
        for step in range(max_new):
            outs[~done, step] = np.asarray(cur)[~done]
            for i, r in enumerate(batch):
                if not done[i] and (outs[i, step] == r.eos_id
                                    or step + 1 >= r.max_tokens):
                    done[i] = True
            if done.all() or plen + step + 1 >= self.max_len:
                break
            cur, caches = self._jit_decode(self.params, caches, cur[:, None],
                                           jnp.int32(plen + step))
            self.stats["decode_steps"] += 1

        for i, r in enumerate(batch):
            got = outs[i][outs[i] >= 0].astype(np.int32)
            r.tokens_out = got
            r.done_t = time.monotonic()
            r.fsm.transition(states.REQUEST_RECEIVED, states.REQUEST_COMPLETED)
            self.pool.free(r.req_id)
            self.stats["served"] += 1
            self._respond(r)
        self.stats["batches"] += 1

    def _respond(self, req: Request) -> None:
        ring = self.responses[req.client_id]
        while ring.insert_item(req) != nbb.OK:
            time.sleep(0)          # response ring full: yield, retry

    def step(self) -> int:
        """One engine iteration; returns requests served."""
        batch = self._take_batch()
        if not batch:
            return 0
        self._run_batch(batch)
        return len(batch)

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                time.sleep(0.001)

    def start(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def stop(self) -> None:
        self._stop.set()

    # -- client-side receive -----------------------------------------------------
    def get_response(self, client_id: int, timeout_s: float = 30.0
                     ) -> Optional[Request]:
        deadline = time.monotonic() + timeout_s
        ring = self.responses[client_id]
        while time.monotonic() < deadline:
            status, req = ring.read_item()
            if status == nbb.OK:
                return req
            time.sleep(0.001)
        return None
