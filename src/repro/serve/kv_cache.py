"""Paged KV-cache pool with a lock-free refcounted page allocator.

The serving engine's KV memory is a fixed pool of fixed-size pages (the
vLLM idea, TPU-adapted: pages are [page_size, kv_heads, head_dim] tiles
whose last two dims stay MXU/VREG aligned).  Page accounting uses the
refcounted generalization of the paper's lock-free **bit set**
(refactoring step 3): claim-from-zero is a single CAS on a
:class:`RefCountArray` slot, share/release are wait-free fetch-add /
fetch-sub, and a page re-enters the free set exactly when its count hits
zero — so concurrent client threads admitting requests never serialize
behind a pool lock, and one physical page can back many sequences'
block-table rows at once.  Admission control stays non-blocking and
over-subscription is rejected with an explicit status (the NBB
BUFFER_FULL discipline) rather than a blocked caller.

Prefix sharing rides on the counts (DESIGN.md §11): the
:class:`PrefixCache` maps chained chunk-aligned prompt hashes to page
runs, admission increfs a hit's pages instead of dispatching prefill,
and a write into a page whose count exceeds one is gated behind
copy-on-write (``ensure_private``): claim a fresh page, device-copy that
one page, repoint the single block-table row, decref the shared
original.  CoW traffic is the only KV copying the paged scheduler ever
performs and is charged honestly to ``kv_copy_bytes`` (mirrored in
``cow_copy_bytes``).  Unreferenced cached prefixes stay resident as an
LRU set and are evicted under pool pressure before any claim fails.

Device-side, per-sequence KV lives scattered across the pool arrays.
Under the paged scheduler (``slot_paged``, DESIGN.md §10) the pool's
``k``/``v`` arrays ARE the device-resident KV store: decode attends
straight through per-slot block tables, and admission/retire only edit
int32 block-table rows and bitset pages.  The gather/scatter
``swap_in``/``swap_out`` pair is the copy-in/copy-out path that
indirection deletes — no scheduler calls it (it survives as the
measured baseline for tests/benchmarks), and every byte it or any
other residency copy moves is charged to the honest ``kv_copy_bytes``
counter, which stays 0 for ``slot_paged`` steady state.

The host-offload preemption tier that pair anticipated now exists
(DESIGN.md §12): ``swap_out_preempt`` parks a victim sequence by moving
its PRIVATE pages to host memory and releasing them — refcount>1 pages
(live prefix shares) are never moved or released, they stay resident for
their other holders and the victim keeps its references; the block-table
rows are parked as ``-1`` tombstones inside a :class:`SwapImage`.
``swap_in_preempt`` re-claims fresh pages all-or-nothing and scatters
the saved bytes back, so a resumed sequence is byte-identical to one
never preempted.  Swap traffic is charged to ``kv_copy_bytes`` and
itemized in ``swap_out_bytes``/``swap_in_bytes`` so the invariant
``kv_copy_bytes == cow_copy_bytes + swap_in_bytes + swap_out_bytes``
holds under ``slot_paged``.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core.refcount import RefCountArray

OK = 0
POOL_FULL = 1


@dataclasses.dataclass
class PageTable:
    """Host-side metadata for one sequence's pages.

    ``slot`` is the decode slot the sequence is bound to in the slot-swap
    engine (None for wave scheduling / unbound sequences); ``n_reserved``
    records the admission-time reservation so utilization stats can report
    how much of the reservation a sequence actually consumed.
    """
    seq_id: int
    pages: List[int]
    n_tokens: int = 0
    slot: Optional[int] = None
    n_reserved: int = 0


@dataclasses.dataclass
class SwapImage:
    """Host-side parking record for one preempted sequence (DESIGN.md
    §12).  ``rows`` are the block-table rows whose private live pages
    were gathered into ``k``/``v`` (numpy, one entry per row, in row
    order); ``dead_rows`` were reserved-ahead (never-attended) pages
    released without copying; ``shared_rows`` are refcount>1 prefix
    pages that never moved — the sequence keeps its references and the
    block-table rows stay valid while parked."""
    seq_id: int
    rows: List[int]
    k: "np.ndarray"
    v: "np.ndarray"
    dead_rows: List[int]
    shared_rows: List[int]

    @property
    def nbytes(self) -> int:
        return int(self.k.nbytes) + int(self.v.nbytes)


class PagedKVPool:
    """One pool per (layer-stacked) KV tensor family.

    k/v pools: [n_pages, page_size, n_layers, kv_heads, head_dim] — layer
    innermost-batched so one page holds all layers for a token span and a
    sequence needs ceil(len/page_size) pages total (not per layer).
    """

    def __init__(self, n_pages: int, page_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.n_pages, self.page_size = n_pages, page_size
        self.n_layers, self.kv_heads, self.head_dim = (n_layers, kv_heads,
                                                       head_dim)
        shape = (n_pages, page_size, n_layers, kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self._alloc = RefCountArray(n_pages)
        self._tables: Dict[int, PageTable] = {}
        self._next_probe = 0
        # Honest KV-traffic counters (DESIGN.md §10): every byte a
        # scheduler moves to (re)establish residency is charged here —
        # swap_in/swap_out page traffic and the engine's dense
        # cache-admission copies.  The paged scheduler's steady state
        # performs no KV copies at all, so its counter stays 0 until a
        # copy-on-write fires (``cow_copy_bytes`` isolates that share).
        self.kv_copy_bytes = 0
        self.cow_copy_bytes = 0
        self.swap_in_bytes = 0
        self.swap_out_bytes = 0
        self._peak_pages = 0
        self._shared_peak = 0
        # Pool-pressure escape hatch: the prefix cache registers its LRU
        # evictor here so resident-but-unreferenced prefixes yield their
        # pages before any claim fails (DESIGN.md §11).
        self._evict: Optional[Callable[[], bool]] = None
        self._cow_fns: Dict[int, Callable] = {}
        self._swap_fns: Dict[int, Callable] = {}
        # Fault-injection plan (DESIGN.md §13): armed by the engine;
        # every probe below is one ``is None`` check when disarmed.
        self.faults: Optional["faults_mod.FaultPlan"] = None
        # Pages implicated in a failed/poisoned write, pinned out of
        # circulation: quarantine holds one extra reference, so when the
        # owning sequence frees, the count lands at 1 — never 0 — and
        # claim-from-zero can never hand the page out again.
        self.quarantined: set = set()

    # -- allocation (lock-free) ------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    def _claim_pages(self, n: int) -> Optional[List[int]]:
        """THE page-claim loop (every reservation path goes through it):
        claim ``n`` pages lock-free, all-or-nothing — on shortage the
        partial claim is rolled back and None returned, so concurrent
        admitters can't deadlock each other or strand half-claims."""
        if (n > 0 and self.faults is not None
                and self.faults.fire("pool.claim") is not None):
            return None                 # injected shortage: pre-claim, clean
        got: List[int] = []
        for _ in range(n):
            while True:
                # fresh token per claim: setdefault-CAS must not recognize
                # our own earlier claims as "won again"
                page = self._alloc.try_claim(owner=object(),
                                             start=self._next_probe)
                if page is not None:
                    break
                # Pool pressure: evict an unreferenced cached prefix and
                # retry before declaring shortage.  Eviction only drops
                # the cache's references, so a page still backing a live
                # sequence never leaves the pool here.
                if self._evict is None or not self._evict():
                    for p in got:  # roll back — nobody waits on us
                        self._alloc.release(p)
                    return None
            self._next_probe = (page + 1) % self.n_pages
            got.append(page)
        self._peak_pages = max(self._peak_pages, self.used_pages())
        return got

    def set_pressure_callback(self,
                              evict: Optional[Callable[[], bool]]) -> None:
        """Install the evict-one-prefix-under-pressure hook (returns True
        when it released something worth retrying the claim for)."""
        self._evict = evict

    @property
    def page_nbytes(self) -> int:
        """Device bytes one page occupies across both pool arrays."""
        return int(self.k[0].nbytes) + int(self.v[0].nbytes)

    def reset_traffic(self) -> None:
        """Zero the copy/peak counters (benchmark pass boundaries)."""
        self.kv_copy_bytes = 0
        self.cow_copy_bytes = 0
        self.swap_in_bytes = 0
        self.swap_out_bytes = 0
        self._peak_pages = self.used_pages()
        self._shared_peak = self._alloc.shared_count()

    def try_admit(self, seq_id: int, n_tokens: int,
                  slot: Optional[int] = None) -> int:
        """Claim pages for a sequence.  OK or POOL_FULL (all-or-nothing).
        ``slot`` binds the reservation to a decode slot for per-slot
        accounting."""
        got = self._claim_pages(self.pages_needed(n_tokens))
        if got is None:
            return POOL_FULL
        self._tables[seq_id] = PageTable(seq_id, got, n_tokens, slot=slot,
                                         n_reserved=n_tokens)
        return OK

    # -- prefix sharing (refcounts + copy-on-write, DESIGN.md §11) -------------
    def adopt_shared(self, seq_id: int, pages: List[int], n_tokens: int,
                     slot: Optional[int] = None) -> None:
        """Admit a sequence onto already-resident prefix pages: one incref
        per page and an int32 block-table row — no device dispatch, no
        claim that can fail.  ``n_tokens`` is the prefix extent the pages
        cover (the sequence resumes prefill there)."""
        for p in pages:
            self._alloc.incref(p)
        self._tables[seq_id] = PageTable(seq_id, list(pages), n_tokens,
                                         slot=slot, n_reserved=n_tokens)
        self._note_sharing()
        self._peak_pages = max(self._peak_pages, self.used_pages())

    def incref_pages(self, pages: List[int]) -> None:
        """Take one reference per page (prefix-cache residency)."""
        for p in pages:
            self._alloc.incref(p)
        self._note_sharing()

    def decref_pages(self, pages: List[int]) -> None:
        """Drop one reference per page; pages whose count reaches zero
        re-enter the free set (prefix-cache eviction)."""
        for p in pages:
            self._alloc.decref(p)

    def refcount(self, page: int) -> int:
        return self._alloc.refcount(page)

    def _note_sharing(self) -> None:
        self._shared_peak = max(self._shared_peak,
                                self._alloc.shared_count())

    def ensure_private(self, seq_id: int, start_pos: int,
                       end_pos: int) -> int:
        """Copy-on-write gate: before a dispatch writes KV positions
        ``[start_pos, end_pos)`` of a sequence, repoint every page in
        that range that another holder can still read.  Per shared page:
        claim a fresh page, device-copy exactly that page, swap the one
        block-table row, decref the original (which stays resident for
        its other holders).  All-or-nothing like every claim path."""
        if end_pos <= start_pos:
            return OK
        t = self._tables[seq_id]
        ps = self.page_size
        first = start_pos // ps
        last = min((end_pos - 1) // ps, len(t.pages) - 1)
        rows = [i for i in range(first, last + 1)
                if self._alloc.refcount(t.pages[i]) > 1]
        if not rows:
            return OK
        if (self.faults is not None
                and self.faults.fire("pool.cow") is not None):
            return POOL_FULL            # injected: before any claim or copy
        fresh = self._claim_pages(len(rows))
        if fresh is None:
            return POOL_FULL
        self._copy_pages([t.pages[i] for i in rows], fresh)
        nbytes = len(rows) * self.page_nbytes
        self.kv_copy_bytes += nbytes
        self.cow_copy_bytes += nbytes
        for i, new_p in zip(rows, fresh):
            old = t.pages[i]
            t.pages[i] = new_p
            self._alloc.decref(old)
        return OK

    def _copy_pages(self, src: List[int], dst: List[int]) -> None:
        """One fused device dispatch copying len(src) whole pages inside
        the pool arrays (donated, so XLA updates in place)."""
        fn = self._cow_fns.get(len(src))
        if fn is None:
            fn = jax.jit(lambda k, v, s, d: (k.at[d].set(k[s]),
                                             v.at[d].set(v[s])),
                         donate_argnums=(0, 1))
            self._cow_fns[len(src)] = fn
        s = jnp.asarray(src, jnp.int32)
        d = jnp.asarray(dst, jnp.int32)
        self.k, self.v = fn(self.k, self.v, s, d)

    def note_tokens(self, seq_id: int, n_tokens: int) -> None:
        """Record decode growth inside the existing reservation (no page
        traffic; keeps per-slot utilization stats truthful)."""
        self._tables[seq_id].n_tokens = n_tokens

    def extend_reservation(self, seq_id: int, n_tokens: int) -> int:
        """Grow a sequence's *reservation* to cover ``n_tokens`` without
        recording them as written (chunked admission, DESIGN.md §9: pages
        are claimed chunk by chunk as prompt positions materialize, then
        the decode budget is reserved with the final chunk).  All-or-
        nothing, so a mid-stream admission under memory pressure aborts
        cleanly instead of holding half its pages.  ``note_tokens``
        still reports actual written growth."""
        t = self._tables[seq_id]
        need = self.pages_needed(n_tokens) - len(t.pages)
        if (need > 0 and self.faults is not None
                and self.faults.fire("pool.extend") is not None):
            return POOL_FULL            # injected: table untouched
        got = self._claim_pages(need)
        if got is None:
            return POOL_FULL
        t.pages.extend(got)
        t.n_reserved = max(t.n_reserved, n_tokens)
        return OK

    def grow(self, seq_id: int, new_n_tokens: int) -> int:
        """Extend a sequence (decode appends); claims pages as needed."""
        t = self._tables[seq_id]
        got = self._claim_pages(self.pages_needed(new_n_tokens)
                                - len(t.pages))
        if got is None:
            return POOL_FULL
        t.pages.extend(got)
        t.n_tokens = new_n_tokens
        return OK

    def free(self, seq_id: int) -> None:
        t = self._tables.pop(seq_id)
        for p in t.pages:
            if p >= 0:  # skip swap tombstones of a parked sequence
                self._alloc.release(p)

    def quarantine_range(self, seq_id: int, start_pos: int,
                         end_pos: int) -> List[int]:
        """Remove the pages backing positions ``[start_pos, end_pos)`` of
        a sequence from circulation after a failed/poisoned write
        (DESIGN.md §13).  Only PRIVATE pages (refcount == 1) are pinned:
        a shared page's bytes predate the failed write — other holders
        adopted it from a committed prefix — so it is provably clean.
        Pinning is one incref; the page is permanently accounted as used
        (``free_pages`` stays exact) and, because claims only win on
        count zero, it can never back a future sequence.  Idempotent per
        page.  Returns the pages quarantined by THIS call."""
        t = self._tables.get(seq_id)
        if t is None or end_pos <= start_pos:
            return []
        ps = self.page_size
        first = max(0, start_pos // ps)
        last = min((end_pos - 1) // ps, len(t.pages) - 1)
        got: List[int] = []
        for i in range(first, last + 1):
            p = t.pages[i]
            if (p >= 0 and p not in self.quarantined
                    and self._alloc.refcount(p) == 1):
                self._alloc.incref(p)
                self.quarantined.add(p)
                got.append(p)
        return got

    def free_pages(self) -> int:
        return self.n_pages - self._alloc.count()

    def used_pages(self) -> int:
        return self._alloc.count()

    def n_seqs(self) -> int:
        return len(self._tables)

    def table(self, seq_id: int) -> PageTable:
        return self._tables[seq_id]

    def stats(self) -> Dict[str, object]:
        """Snapshot for occupancy/utilization reporting: overall page use
        plus a per-slot breakdown {slot: (pages, tokens, reserved)}."""
        per_slot = {
            t.slot: (len(t.pages), t.n_tokens, t.n_reserved)
            for t in self._tables.values() if t.slot is not None
        }
        return {"n_pages": self.n_pages, "used": self.used_pages(),
                "free": self.free_pages(), "seqs": self.n_seqs(),
                "per_slot": per_slot,
                # Length-proportional residency (DESIGN.md §10): bytes
                # of pool pages the live sequences actually hold (and
                # the high-water mark), vs the dense batch cache's fixed
                # O(B * max_len) — plus every byte any scheduler spent
                # COPYING KV to establish residency (0 for slot_paged).
                # Under sharing, ``used_pages`` counts each *physical*
                # page once however many block-table rows point at it —
                # residency reflects HBM actually held, not the sum of
                # per-sequence views.
                "kv_resident_bytes": self.used_pages() * self.page_nbytes,
                "kv_resident_bytes_peak": self._peak_pages * self.page_nbytes,
                "kv_copy_bytes": self.kv_copy_bytes,
                "cow_copy_bytes": self.cow_copy_bytes,
                "swap_in_bytes": self.swap_in_bytes,
                "swap_out_bytes": self.swap_out_bytes,
                "shared_pages": self._alloc.shared_count(),
                "shared_pages_peak": self._shared_peak,
                "quarantined": len(self.quarantined)}

    # -- device data movement (RETIRED: no scheduler calls these) ---------------
    # Residency under ``slot_paged`` is established by writing int32
    # block-table rows, not by moving HBM.  The pair remains only as
    # the measured "what the block table deletes" baseline
    # (benchmarks/bench_kernels.py, tests) and as the copy hook a
    # host-offload preemption tier would charge to ``kv_copy_bytes``.
    def swap_in(self, seq_id: int, max_len: int
                ) -> Tuple[jax.Array, jax.Array]:
        """Gather a sequence's pages -> contiguous [max_len, L, kv, hd] k/v."""
        t = self._tables[seq_id]
        self.kv_copy_bytes += len(t.pages) * self.page_nbytes
        idx = jnp.asarray(t.pages, jnp.int32)
        k = self.k[idx].reshape(-1, self.n_layers, self.kv_heads,
                                self.head_dim)
        v = self.v[idx].reshape(-1, self.n_layers, self.kv_heads,
                                self.head_dim)
        pad = max_len - k.shape[0]
        if pad > 0:
            zk = jnp.zeros((pad,) + k.shape[1:], k.dtype)
            k, v = jnp.concatenate([k, zk]), jnp.concatenate([v, zk])
        return k[:max_len], v[:max_len]

    def swap_out(self, seq_id: int, k_seq: jax.Array, v_seq: jax.Array,
                 n_tokens: int) -> int:
        """Scatter contiguous [S, L, kv, hd] k/v back into the pool."""
        status = self.grow(seq_id, n_tokens)
        if status != OK:
            return status
        t = self._tables[seq_id]
        ps = self.page_size
        n_pages = self.pages_needed(n_tokens)
        self.kv_copy_bytes += n_pages * self.page_nbytes
        pad = n_pages * ps - k_seq.shape[0]
        if pad > 0:
            zk = jnp.zeros((pad,) + k_seq.shape[1:], k_seq.dtype)
            k_seq = jnp.concatenate([k_seq, zk])
            v_seq = jnp.concatenate([v_seq, zk])
        idx = jnp.asarray(t.pages[:n_pages], jnp.int32)
        k_pages = k_seq[:n_pages * ps].reshape(n_pages, ps, self.n_layers,
                                               self.kv_heads, self.head_dim)
        v_pages = v_seq[:n_pages * ps].reshape(n_pages, ps, self.n_layers,
                                               self.kv_heads, self.head_dim)
        self.k = self.k.at[idx].set(k_pages)
        self.v = self.v.at[idx].set(v_pages)
        return OK

    # -- page-swap preemption (the overload tier, DESIGN.md §12) -------------
    def swap_out_preempt(self, seq_id: int, n_live_tokens: int) -> SwapImage:
        """Park a sequence host-side, releasing its pool pages.

        Page disposition, by rule not position:
          * refcount > 1 (live prefix shares) — NEVER moved or
            released; the victim keeps its references and the rows stay
            valid, so a preempted prefix-cache hit leaves the shared
            pages resident and ``cow_copy_bytes`` untouched.
          * refcount == 1, row < live extent — gathered to host in one
            indexed read, then released (``-1`` tombstone in the row).
          * refcount == 1, row >= live extent (reserved-ahead pages no
            token was ever written to) — released without copying;
            resume re-claims them blank, and positions past the live
            extent are never attended before being rewritten.

        Only the copied bytes are charged (``swap_out_bytes``, mirrored
        into ``kv_copy_bytes``).  The caller parks the returned image
        with its BUFFER_PREEMPTED cell and later hands it back to
        :meth:`swap_in_preempt`.
        """
        if (self.faults is not None
                and self.faults.fire("pool.swap_out") is not None):
            # Raised before ANY mutation: the victim's pages, table and
            # counters are untouched, so the engine treats this exactly
            # like "no preemptible victim" and the needer takes the
            # ordinary rejection path.
            raise faults_mod.InjectedFault("pool.swap_out",
                                           self.faults.n_fired,
                                           retryable=True)
        t = self._tables[seq_id]
        live = 0 if n_live_tokens <= 0 else self.pages_needed(n_live_tokens)
        rows: List[int] = []
        dead_rows: List[int] = []
        shared_rows: List[int] = []
        for i, p in enumerate(t.pages):
            if p < 0:
                continue
            if self._alloc.refcount(p) > 1:
                shared_rows.append(i)
            elif i < live:
                rows.append(i)
            else:
                dead_rows.append(i)
        if rows:
            idx = jnp.asarray([t.pages[i] for i in rows], jnp.int32)
            k_host = np.asarray(self.k[idx])
            v_host = np.asarray(self.v[idx])
        else:
            shape = (0, self.page_size, self.n_layers, self.kv_heads,
                     self.head_dim)
            k_host = np.zeros(shape, np.asarray(self.k[0:0]).dtype)
            v_host = k_host
        for i in rows + dead_rows:
            self._alloc.release(t.pages[i])
            t.pages[i] = -1
        nbytes = len(rows) * self.page_nbytes
        self.swap_out_bytes += nbytes
        self.kv_copy_bytes += nbytes
        t.slot = None
        return SwapImage(seq_id, rows, k_host, v_host, dead_rows,
                         shared_rows)

    def swap_in_preempt(self, seq_id: int, image: SwapImage) -> int:
        """Re-establish a parked sequence's residency: claim fresh pages
        for every tombstoned row (all-or-nothing — POOL_FULL leaves the
        image and table untouched for a later retry), scatter the saved
        bytes back in one fused donated dispatch, and leave the shared
        rows alone (they never left).  The resumed sequence reads back
        byte-identical: pages moved wholesale, and the block-table
        indirection makes the new physical page numbers invisible."""
        if (self.faults is not None
                and self.faults.fire("pool.swap_in") is not None):
            return POOL_FULL            # injected: image stays parked
        t = self._tables[seq_id]
        need = len(image.rows) + len(image.dead_rows)
        got = self._claim_pages(need)
        if got is None:
            return POOL_FULL
        for i, p in zip(image.rows + image.dead_rows, got):
            t.pages[i] = p
        if image.rows:
            fn = self._swap_fns.get(len(image.rows))
            if fn is None:
                fn = jax.jit(lambda k, v, d, kh, vh: (k.at[d].set(kh),
                                                      v.at[d].set(vh)),
                             donate_argnums=(0, 1))
                self._swap_fns[len(image.rows)] = fn
            d = jnp.asarray(got[:len(image.rows)], jnp.int32)
            self.k, self.v = fn(self.k, self.v, d,
                                jnp.asarray(image.k), jnp.asarray(image.v))
        nbytes = len(image.rows) * self.page_nbytes
        self.swap_in_bytes += nbytes
        self.kv_copy_bytes += nbytes
        self._peak_pages = max(self._peak_pages, self.used_pages())
        return OK

    # -- crash-recovery snapshots (DESIGN.md §14) ------------------------------
    def snapshot_state(self, extra_pages=()) -> Dict[str, object]:
        """Host-side image of the pool at a tick boundary: block tables,
        per-page refcounts, quarantine pins, traffic counters, and the
        bytes of every page that backs *written* positions — gathered
        once per distinct physical page in a single device fetch, so a
        page shared by N sequences (or pinned by a prefix-cache entry)
        costs one page of host memory, not N.  Reserved-ahead pages
        (rows beyond ``pages_needed(n_tokens)``) carry no bytes: nothing
        attended lives there, so restore re-claims them blank.
        ``extra_pages`` lets the engine pin prefix-cache-resident pages
        whose owning sequences have already retired."""
        refcounts = {p: self._alloc.refcount(p) for p in range(self.n_pages)
                     if self._alloc.refcount(p) > 0}
        tables = {sid: {"pages": list(t.pages), "n_tokens": t.n_tokens,
                        "slot": t.slot, "n_reserved": t.n_reserved}
                  for sid, t in self._tables.items()}
        need = {int(p) for p in extra_pages}
        for t in self._tables.values():
            if t.n_tokens > 0:
                live = min(self.pages_needed(t.n_tokens), len(t.pages))
                need.update(p for p in t.pages[:live] if p >= 0)
        idx = sorted(need)
        if idx:
            ii = jnp.asarray(idx, jnp.int32)
            k_host = np.asarray(self.k[ii])
            v_host = np.asarray(self.v[ii])
        else:
            k_host = v_host = np.zeros((0,), np.int8)
        return {
            "n_pages": self.n_pages, "page_size": self.page_size,
            "refcounts": refcounts, "tables": tables,
            "quarantined": set(self.quarantined),
            "next_probe": self._next_probe,
            "counters": {
                "kv_copy_bytes": self.kv_copy_bytes,
                "cow_copy_bytes": self.cow_copy_bytes,
                "swap_in_bytes": self.swap_in_bytes,
                "swap_out_bytes": self.swap_out_bytes,
                "peak_pages": self._peak_pages,
                "shared_peak": self._shared_peak,
            },
            "data_pages": idx, "k": k_host, "v": v_host,
        }

    def reset(self) -> None:
        """Return the pool to its just-constructed state: zeroed device
        arrays (stale bytes from a previous incarnation must not leak
        into restored sequences), a fresh allocator, no tables, no
        quarantine, zero counters.  Compiled CoW/swap traces survive."""
        shape = (self.n_pages, self.page_size, self.n_layers,
                 self.kv_heads, self.head_dim)
        dtype = self.k.dtype
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self._alloc = RefCountArray(self.n_pages)
        self._tables = {}
        self._next_probe = 0
        self.quarantined = set()
        self.kv_copy_bytes = 0
        self.cow_copy_bytes = 0
        self.swap_in_bytes = 0
        self.swap_out_bytes = 0
        self._peak_pages = 0
        self._shared_peak = 0

    def restore_state(self, state: Dict[str, object]) -> None:
        """Rebuild the pool from a :meth:`snapshot_state` image.  Every
        physical page id is re-claimed at its exact saved refcount via
        ``RefCountArray.claim_specific`` — block tables restore verbatim,
        so post-restore decode reads the same page numbers the snapshot
        recorded.  Saved page bytes scatter back in one fused dispatch
        (reusing the swap-in trace cache); traffic counters restore to
        their snapshotted values, so the copy ledger stays exact across
        the restart (the restore scatter itself is recovery traffic, not
        scheduler traffic, and is deliberately not charged)."""
        if (state["n_pages"] != self.n_pages
                or state["page_size"] != self.page_size):
            raise ValueError(
                f"pool shape mismatch: snapshot {state['n_pages']}p x "
                f"{state['page_size']}, pool {self.n_pages}p x "
                f"{self.page_size}")
        self.reset()
        for p, n in state["refcounts"].items():
            if not self._alloc.claim_specific(p):
                raise RuntimeError(f"page {p} not claimable on restore")
            for _ in range(n - 1):
                self._alloc.incref(p)
        self._tables = {
            sid: PageTable(sid, list(d["pages"]), d["n_tokens"],
                           slot=d["slot"], n_reserved=d["n_reserved"])
            for sid, d in state["tables"].items()}
        self.quarantined = set(state["quarantined"])
        self._next_probe = state["next_probe"]
        c = state["counters"]
        self.kv_copy_bytes = c["kv_copy_bytes"]
        self.cow_copy_bytes = c["cow_copy_bytes"]
        self.swap_in_bytes = c["swap_in_bytes"]
        self.swap_out_bytes = c["swap_out_bytes"]
        self._peak_pages = c["peak_pages"]
        self._shared_peak = c["shared_peak"]
        idx = state["data_pages"]
        if idx:
            fn = self._swap_fns.get(len(idx))
            if fn is None:
                fn = jax.jit(lambda k, v, d, kh, vh: (k.at[d].set(kh),
                                                      v.at[d].set(vh)),
                             donate_argnums=(0, 1))
                self._swap_fns[len(idx)] = fn
            d = jnp.asarray(idx, jnp.int32)
            self.k, self.v = fn(self.k, self.v, d,
                                jnp.asarray(state["k"]),
                                jnp.asarray(state["v"]))


@dataclasses.dataclass
class PrefixEntry:
    """One cached chunk-aligned prompt prefix: ``pages`` cover the first
    ``n_tokens`` positions of the (bucketed, left-padded) token stream
    whose chained chunk hash is ``key``.  The entry holds one reference
    per page, so the pages stay resident after every sequence that wrote
    or shared them has retired."""
    key: int
    n_tokens: int
    pages: List[int]
    tick: int = 0


class PrefixCache:
    """LRU map from chained chunk hashes to resident page runs.

    Hashes are chained (each chunk's hash folds in its predecessor's),
    so an entry for a shallow prefix of a cached deep prefix is its own
    key — a lookup walks candidate depths deepest-first and the first
    present entry wins, which is how "a prefix of a cached prefix also
    hits".  The cache registers its LRU evictor as the pool's pressure
    callback: page claims evict unreferenced prefixes before failing.
    """

    def __init__(self, pool: PagedKVPool):
        self.pool = pool
        self._entries: Dict[int, PrefixEntry] = {}
        self._clock = itertools.count()
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        pool.set_pressure_callback(self.evict_lru)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: int) -> bool:
        return key in self._entries

    def insert(self, key: int, n_tokens: int, pages: List[int]) -> bool:
        """Cache a prefix: one incref per page (the cache's own
        residency).  Idempotent per key — re-inserting bumps LRU only."""
        ent = self._entries.get(key)
        if ent is not None:
            ent.tick = next(self._clock)
            return False
        self.pool.incref_pages(pages)
        self._entries[key] = PrefixEntry(key, n_tokens, list(pages),
                                         next(self._clock))
        self.insertions += 1
        return True

    def lookup(self, keys: List[int]) -> Optional[PrefixEntry]:
        """Deepest-first probe: ``keys`` are chained hashes ordered
        deepest prefix first; the first cached one wins."""
        for key in keys:
            ent = self._entries.get(key)
            if ent is not None:
                ent.tick = next(self._clock)
                self.hits += 1
                return ent
        self.misses += 1
        return None

    def evict_key(self, key: int) -> bool:
        """Drop one entry's references (LRU eviction and the engine's
        abort rollback).  Pages no live sequence shares return to the
        free set; pages still backing sequences merely lose the cache's
        claim on them — never freed out from under a holder."""
        ent = self._entries.pop(key, None)
        if ent is None:
            return False
        self.pool.decref_pages(ent.pages)
        self.evictions += 1
        return True

    def evict_lru(self) -> bool:
        """Evict the least-recently-used prefix (pool-pressure hook)."""
        if not self._entries:
            return False
        return self.evict_key(
            min(self._entries, key=lambda k: self._entries[k].tick))

    def clear(self) -> None:
        while self.evict_lru():
            pass

    def resident_pages(self) -> set:
        """Physical pages the cache holds references on (each once)."""
        out: set = set()
        for ent in self._entries.values():
            out.update(ent.pages)
        return out

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "insertions": self.insertions,
                "evictions": self.evictions,
                "resident_pages": len(self.resident_pages())}
