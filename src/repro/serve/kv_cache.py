"""Paged KV-cache pool with a lock-free bitset page allocator.

The serving engine's KV memory is a fixed pool of fixed-size pages (the
vLLM idea, TPU-adapted: pages are [page_size, kv_heads, head_dim] tiles
whose last two dims stay MXU/VREG aligned).  Page accounting uses the
paper's lock-free **bit set** (refactoring step 3): claim-any-free-page
and release-page are single-CAS operations on a :class:`HostBitset`, so
concurrent client threads admitting requests never serialize behind a
pool lock — admission control is non-blocking and over-subscription is
rejected with an explicit status (the NBB BUFFER_FULL discipline) rather
than a blocked caller.

Device-side, per-sequence KV lives scattered across the pool arrays.
Under the paged scheduler (``slot_paged``, DESIGN.md §10) the pool's
``k``/``v`` arrays ARE the device-resident KV store: decode attends
straight through per-slot block tables, and admission/retire only edit
int32 block-table rows and bitset pages.  The gather/scatter
``swap_in``/``swap_out`` pair is the copy-in/copy-out path that
indirection deletes — no scheduler calls it (it survives as the
measured baseline for tests/benchmarks and as the hook a future
host-offload preemption tier would use), and every byte it or any
other residency copy moves is charged to the honest ``kv_copy_bytes``
counter, which stays 0 for ``slot_paged``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bitset import HostBitset

OK = 0
POOL_FULL = 1


@dataclasses.dataclass
class PageTable:
    """Host-side metadata for one sequence's pages.

    ``slot`` is the decode slot the sequence is bound to in the slot-swap
    engine (None for wave scheduling / unbound sequences); ``n_reserved``
    records the admission-time reservation so utilization stats can report
    how much of the reservation a sequence actually consumed.
    """
    seq_id: int
    pages: List[int]
    n_tokens: int = 0
    slot: Optional[int] = None
    n_reserved: int = 0


class PagedKVPool:
    """One pool per (layer-stacked) KV tensor family.

    k/v pools: [n_pages, page_size, n_layers, kv_heads, head_dim] — layer
    innermost-batched so one page holds all layers for a token span and a
    sequence needs ceil(len/page_size) pages total (not per layer).
    """

    def __init__(self, n_pages: int, page_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.n_pages, self.page_size = n_pages, page_size
        self.n_layers, self.kv_heads, self.head_dim = (n_layers, kv_heads,
                                                       head_dim)
        shape = (n_pages, page_size, n_layers, kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self._alloc = HostBitset(n_pages)
        self._tables: Dict[int, PageTable] = {}
        self._next_probe = 0
        # Honest KV-traffic counters (DESIGN.md §10): every byte a
        # scheduler moves to (re)establish residency is charged here —
        # swap_in/swap_out page traffic and the engine's dense
        # cache-admission copies.  The paged scheduler's steady state
        # performs no KV copies at all, so its counter stays 0.
        self.kv_copy_bytes = 0
        self._peak_pages = 0

    # -- allocation (lock-free) ------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    def _claim_pages(self, n: int) -> Optional[List[int]]:
        """THE page-claim loop (every reservation path goes through it):
        claim ``n`` pages lock-free, all-or-nothing — on shortage the
        partial claim is rolled back and None returned, so concurrent
        admitters can't deadlock each other or strand half-claims."""
        got: List[int] = []
        for _ in range(n):
            # fresh token per claim: setdefault-CAS must not recognize our
            # own earlier claims as "won again"
            page = self._alloc.try_claim(owner=object(),
                                         start=self._next_probe)
            if page is None:
                for p in got:      # roll back — nobody waits on us
                    self._alloc.release(p)
                return None
            self._next_probe = (page + 1) % self.n_pages
            got.append(page)
        self._peak_pages = max(self._peak_pages, self.used_pages())
        return got

    @property
    def page_nbytes(self) -> int:
        """Device bytes one page occupies across both pool arrays."""
        return int(self.k[0].nbytes) + int(self.v[0].nbytes)

    def reset_traffic(self) -> None:
        """Zero the copy/peak counters (benchmark pass boundaries)."""
        self.kv_copy_bytes = 0
        self._peak_pages = self.used_pages()

    def try_admit(self, seq_id: int, n_tokens: int,
                  slot: Optional[int] = None) -> int:
        """Claim pages for a sequence.  OK or POOL_FULL (all-or-nothing).
        ``slot`` binds the reservation to a decode slot for per-slot
        accounting."""
        got = self._claim_pages(self.pages_needed(n_tokens))
        if got is None:
            return POOL_FULL
        self._tables[seq_id] = PageTable(seq_id, got, n_tokens, slot=slot,
                                         n_reserved=n_tokens)
        return OK

    def note_tokens(self, seq_id: int, n_tokens: int) -> None:
        """Record decode growth inside the existing reservation (no page
        traffic; keeps per-slot utilization stats truthful)."""
        self._tables[seq_id].n_tokens = n_tokens

    def extend_reservation(self, seq_id: int, n_tokens: int) -> int:
        """Grow a sequence's *reservation* to cover ``n_tokens`` without
        recording them as written (chunked admission, DESIGN.md §9: pages
        are claimed chunk by chunk as prompt positions materialize, then
        the decode budget is reserved with the final chunk).  All-or-
        nothing, so a mid-stream admission under memory pressure aborts
        cleanly instead of holding half its pages.  ``note_tokens``
        still reports actual written growth."""
        t = self._tables[seq_id]
        got = self._claim_pages(self.pages_needed(n_tokens) - len(t.pages))
        if got is None:
            return POOL_FULL
        t.pages.extend(got)
        t.n_reserved = max(t.n_reserved, n_tokens)
        return OK

    def grow(self, seq_id: int, new_n_tokens: int) -> int:
        """Extend a sequence (decode appends); claims pages as needed."""
        t = self._tables[seq_id]
        got = self._claim_pages(self.pages_needed(new_n_tokens)
                                - len(t.pages))
        if got is None:
            return POOL_FULL
        t.pages.extend(got)
        t.n_tokens = new_n_tokens
        return OK

    def free(self, seq_id: int) -> None:
        t = self._tables.pop(seq_id)
        for p in t.pages:
            self._alloc.release(p)

    def free_pages(self) -> int:
        return self.n_pages - self._alloc.count()

    def used_pages(self) -> int:
        return self._alloc.count()

    def n_seqs(self) -> int:
        return len(self._tables)

    def table(self, seq_id: int) -> PageTable:
        return self._tables[seq_id]

    def stats(self) -> Dict[str, object]:
        """Snapshot for occupancy/utilization reporting: overall page use
        plus a per-slot breakdown {slot: (pages, tokens, reserved)}."""
        per_slot = {
            t.slot: (len(t.pages), t.n_tokens, t.n_reserved)
            for t in self._tables.values() if t.slot is not None
        }
        return {"n_pages": self.n_pages, "used": self.used_pages(),
                "free": self.free_pages(), "seqs": self.n_seqs(),
                "per_slot": per_slot,
                # Length-proportional residency (DESIGN.md §10): bytes
                # of pool pages the live sequences actually hold (and
                # the high-water mark), vs the dense batch cache's fixed
                # O(B * max_len) — plus every byte any scheduler spent
                # COPYING KV to establish residency (0 for slot_paged).
                "kv_resident_bytes": self.used_pages() * self.page_nbytes,
                "kv_resident_bytes_peak": self._peak_pages * self.page_nbytes,
                "kv_copy_bytes": self.kv_copy_bytes}

    # -- device data movement (RETIRED: no scheduler calls these) ---------------
    # Residency under ``slot_paged`` is established by writing int32
    # block-table rows, not by moving HBM.  The pair remains only as
    # the measured "what the block table deletes" baseline
    # (benchmarks/bench_kernels.py, tests) and as the copy hook a
    # host-offload preemption tier would charge to ``kv_copy_bytes``.
    def swap_in(self, seq_id: int, max_len: int
                ) -> Tuple[jax.Array, jax.Array]:
        """Gather a sequence's pages -> contiguous [max_len, L, kv, hd] k/v."""
        t = self._tables[seq_id]
        self.kv_copy_bytes += len(t.pages) * self.page_nbytes
        idx = jnp.asarray(t.pages, jnp.int32)
        k = self.k[idx].reshape(-1, self.n_layers, self.kv_heads,
                                self.head_dim)
        v = self.v[idx].reshape(-1, self.n_layers, self.kv_heads,
                                self.head_dim)
        pad = max_len - k.shape[0]
        if pad > 0:
            zk = jnp.zeros((pad,) + k.shape[1:], k.dtype)
            k, v = jnp.concatenate([k, zk]), jnp.concatenate([v, zk])
        return k[:max_len], v[:max_len]

    def swap_out(self, seq_id: int, k_seq: jax.Array, v_seq: jax.Array,
                 n_tokens: int) -> int:
        """Scatter contiguous [S, L, kv, hd] k/v back into the pool."""
        status = self.grow(seq_id, n_tokens)
        if status != OK:
            return status
        t = self._tables[seq_id]
        ps = self.page_size
        n_pages = self.pages_needed(n_tokens)
        self.kv_copy_bytes += n_pages * self.page_nbytes
        pad = n_pages * ps - k_seq.shape[0]
        if pad > 0:
            zk = jnp.zeros((pad,) + k_seq.shape[1:], k_seq.dtype)
            k_seq = jnp.concatenate([k_seq, zk])
            v_seq = jnp.concatenate([v_seq, zk])
        idx = jnp.asarray(t.pages[:n_pages], jnp.int32)
        k_pages = k_seq[:n_pages * ps].reshape(n_pages, ps, self.n_layers,
                                               self.kv_heads, self.head_dim)
        v_pages = v_seq[:n_pages * ps].reshape(n_pages, ps, self.n_layers,
                                               self.kv_heads, self.head_dim)
        self.k = self.k.at[idx].set(k_pages)
        self.v = self.v.at[idx].set(v_pages)
        return OK
