"""Overload-control subsystem: priority classes, WFQ intake, SLO shed.

The paper's QPN model exists as a *stop criterion*: lock-free exchange
is only worth validating if the system still meets latency guarantees
when intake exceeds capacity.  This module is the engine's answer to
"what happens past saturation" (DESIGN.md §12), built from the same
lock-free parts as the data plane:

  * :class:`OverloadPolicy` — the engine's QoS knobs, passed to
    ``ServeEngine(overload=...)``.  ``None`` keeps the legacy FIFO
    intake byte-for-byte.
  * :class:`PriorityIntake` — the multi-class intake fan-in: one
    :class:`~repro.core.host_queue.MpscQueue` per priority class (so
    every (class, client) pair owns a private SPSC NBB ring and the
    whole structure stays lock-free end to end), drained by
    STRICT-PRIORITY-WITH-AGING: class 0 first, but a nonempty class
    bypassed ``aging_limit`` times is served next and its popped
    request is promoted (preemption immunity) — sustained high-priority
    floods cannot starve lower classes.
  * WEIGHTED FAIR QUEUING within a class: the consumer picks, among the
    nonempty per-client rings, the client with the least virtual time;
    ``charge(client, cost)`` advances a client's virtual time by
    ``cost / weight`` when the engine binds its request (cost = the KV
    footprint, bucketed prompt + generation budget).  One flooding
    client therefore shares capacity by weight instead of winning every
    round-robin slot its burst occupies.
  * :class:`ShedStatus` — typed falsy terminal status (like
    ``OversizeStatus``) for SLO-aware admission: a request whose
    deadline already passed when the batcher pops it is shed at intake
    — early, before it claims pages or a slot — instead of convoying
    the queue, which is precisely the lock-based failure mode the paper
    measures.

Preemption itself (the BUFFER_PREEMPTED page-swap path) lives in the
engine + :class:`~repro.serve.kv_cache.PagedKVPool`; this module only
decides *who goes first*.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.core import nbb
from repro.core.host_queue import MpscQueue

# Priority classes (0 = most urgent, matching the MESSAGE channels'
# MCAPI convention).  The engine accepts any class in
# [0, OverloadPolicy.n_classes); these three name the default tiers.
PRIORITY_HIGH = 0
PRIORITY_NORMAL = 1
PRIORITY_LOW = 2


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """QoS policy for :class:`~repro.serve.engine.ServeEngine`.

    ``priorities``  — multi-class intake (strict priority with aging).
    ``preemption``  — page-swap preemption of lower-class decoding
                      sequences under slot/pool pressure (slot_paged
                      only: pages ARE the KV store there, so swapping
                      them captures the whole sequence state).
    ``wfq``         — weighted fair queuing across clients within a
                      class (per-client virtual time over the MPSC
                      ring's per-producer spans).
    ``aging_limit`` — pops a nonempty class (or a parked sequence) may
                      be bypassed by more urgent work before it is
                      served next with promotion.
    ``slo_s``       — default TTFT deadline; a request older than this
                      at pop time is shed (``ShedStatus``).  None (and
                      per-request ``slo_s=None``) disables shedding.
    ``weights``     — per-client WFQ weights (missing clients get 1.0).
    """

    priorities: bool = True
    preemption: bool = True
    wfq: bool = True
    n_classes: int = 3
    aging_limit: int = 8
    slo_s: Optional[float] = None
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.n_classes < 1:
            raise ValueError("need n_classes >= 1")
        if self.aging_limit < 1:
            raise ValueError("need aging_limit >= 1")


@dataclasses.dataclass(frozen=True)
class ShedStatus:
    """Typed SLO shed from admission: the request waited past its
    deadline before the batcher could pop it, so it was refused at
    intake — no pages claimed, no slot bound, no device work.  Falsy,
    like ``TimeoutStatus``/``OversizeStatus``, and delivered on the
    terminal Request (``handle.status``)."""

    waited_s: float
    slo_s: float
    priority: int

    def __bool__(self) -> bool:
        return False


class PriorityIntake:
    """Multi-class, weighted-fair intake fan-in for the serve engine.

    Structure: ``n_classes`` MpscQueues, each with one private SPSC
    ring per client — every ring keeps the single-writer invariant, so
    the composition is lock-free exactly like the flat MpscQueue it
    replaces.  All consumer-side state (bypass counters, virtual
    times) is owned by the single batcher thread; producers only ever
    touch their own rings.

    Drain order (``pop``):
      1. classes strict-priority (lowest number first), except that a
         nonempty class bypassed ``aging_limit`` consecutive times is
         served next (``promoted=True`` — the engine boosts the popped
         request's effective class so it cannot be instantly
         preempted, closing the livelock);
      2. within a class, WFQ: the nonempty client ring with the least
         virtual time (ties to the lowest client id); round-robin when
         WFQ is off.
    """

    def __init__(self, n_clients: int, policy: OverloadPolicy,
                 capacity_per_producer: int = 64):
        self.policy = policy
        self.n_clients = n_clients
        self.n_classes = policy.n_classes if policy.priorities else 1
        self._queues = [MpscQueue(n_clients, capacity_per_producer)
                        for _ in range(self.n_classes)]
        self._bypassed = [0] * self.n_classes
        self._vtime = [0.0] * n_clients
        w = policy.weights or ()
        self._weights = [float(w[i]) if i < len(w) and w[i] > 0 else 1.0
                         for i in range(n_clients)]

    def clamp(self, priority: int) -> int:
        return max(0, min(self.n_classes - 1, int(priority)))

    def producer(self, client_id: int, priority: int = PRIORITY_NORMAL):
        """The private SPSC ring for (client, class) — single-writer,
        so submission stays a plain Transport ``send``."""
        return self._queues[self.clamp(priority)].producer(client_id)

    # -- consumer side (batcher thread only) --------------------------------
    def _pending(self, cls: int) -> bool:
        return self._queues[cls].pending()

    def highest_pending_class(self) -> Optional[int]:
        """Most urgent class with a committed request right now, or
        None.  Consumer-side probe: concurrent inserts can only make
        the answer conservatively stale (miss brand-new work), never
        invent work."""
        for c in range(self.n_classes):
            if self._pending(c):
                return c
        return None

    def _recv_class(self, cls: int) -> Tuple[int, Optional[Any]]:
        q = self._queues[cls]
        if self.policy.wfq:
            best = None
            for i in range(self.n_clients):
                if len(q.producer(i)) and (
                        best is None
                        or self._vtime[i] < self._vtime[best]):
                    best = i
            if best is not None:
                return q.producer(best).read_item()
        return q.try_recv()

    def pop(self) -> Tuple[int, Optional[Any], bool]:
        """One admission pop: ``(status, item, promoted)``.

        ``promoted`` is True when aging served a class over a more
        urgent nonempty one — the caller should boost the item's
        effective priority so the promotion sticks."""
        pending = [c for c in range(self.n_classes) if self._pending(c)]
        if not pending:
            return nbb.BUFFER_EMPTY, None, False
        pick, promoted = pending[0], False
        for c in pending[1:]:
            if self._bypassed[c] >= self.policy.aging_limit:
                pick, promoted = c, True
                break
        for c in pending:
            if c != pick:
                self._bypassed[c] += 1
        self._bypassed[pick] = 0
        status, item = self._recv_class(pick)
        if status != nbb.OK:
            return status, None, False
        return nbb.OK, item, promoted

    def try_recv(self) -> Tuple[int, Optional[Any]]:
        """Transport-shaped pop (promotion flag dropped) so schedulers
        written against the flat MpscQueue keep working."""
        status, item, _ = self.pop()
        return status, item

    def charge(self, client_id: int, cost: float) -> None:
        """Advance a client's WFQ virtual time by ``cost / weight``.
        Called by the engine when it BINDS the client's request (cost =
        the request's KV footprint), not at pop — shed/cancelled
        requests consume no capacity, so they cost nothing."""
        if self.policy.wfq:
            self._vtime[client_id] += cost / self._weights[client_id]

    def vtimes(self) -> List[float]:
        """Snapshot of per-client virtual times (stats/tests)."""
        return list(self._vtime)
