"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` so the
kernel bodies execute in Python for correctness validation; on TPU they
lower to Mosaic.  ``interpret=None`` (default) auto-detects.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.nbb_matmul import nbb_matmul as _nbb_matmul
from repro.kernels import ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Blocked online-softmax GQA attention (see flash_attention.py)."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k,
                  interpret=_auto_interpret(interpret))


def nbb_matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
               interpret: Optional[bool] = None) -> jax.Array:
    """Explicit 2-slot NBB double-buffered matmul (see nbb_matmul.py)."""
    return _nbb_matmul(a, b, bm=bm, bn=bn, bk=bk,
                       interpret=_auto_interpret(interpret))


__all__ = ["flash_attention", "nbb_matmul", "ref"]
