"""NBB double-buffered matmul — the paper's ring buffer on a TPU core.

This kernel is the most literal TPU translation of the paper's NBB
(non-blocking buffer, Kim'07): a 2-slot VMEM ring per operand where the
DMA engine is the *producer* and the MXU is the *consumer*.  The two NBB
atomic counters (update / acknowledge) become the ring indices
``k+1 mod 2`` (slot being filled) and ``k mod 2`` (slot being consumed);
DMA-completion semaphores carry the counter hand-off that x86 used atomic
increments for.  Slot disjointness is guaranteed by construction — the
producer is always exactly one step ahead — so the consumer never waits
on a lock, only on data readiness (the non-blocking property).

Operands live in HBM (``memory_space=ANY``); the kernel hand-rolls the
HBM->VMEM pipeline instead of using BlockSpec auto-pipelining, which is
the point: it demonstrates the NBB discipline explicitly.

Grid = (M//bm, N//bn); inner fori_loop over K//bk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.7 names the TPU compiler options TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _nbb_matmul_kernel(a_hbm, b_hbm, o_ref, a_ring, b_ring, acc_ref,
                       in_sems, *, bm, bn, bk, n_k):
    mi = pl.program_id(0)
    ni = pl.program_id(1)

    def slot_copy(kk, slot):
        """Start the DMA that fills ring slot ``slot`` with K-tile ``kk``."""
        a_dma = pltpu.make_async_copy(
            a_hbm.at[pl.ds(mi * bm, bm), pl.ds(kk * bk, bk)],
            a_ring.at[slot], in_sems.at[slot, 0])
        b_dma = pltpu.make_async_copy(
            b_hbm.at[pl.ds(kk * bk, bk), pl.ds(ni * bn, bn)],
            b_ring.at[slot], in_sems.at[slot, 1])
        a_dma.start()
        b_dma.start()
        return a_dma, b_dma

    # Prime the pipeline: producer fills slot 0 (write counter = 1).
    slot_copy(0, 0)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(kk, _):
        slot = jax.lax.rem(kk, 2)
        nxt = jax.lax.rem(kk + 1, 2)

        # Producer: start filling the *other* slot (non-blocking insert).
        @pl.when(kk + 1 < n_k)
        def _produce():
            slot_copy(kk + 1, nxt)

        # Consumer: wait for slot readiness (data dependency, not a lock).
        pltpu.make_async_copy(
            a_hbm.at[pl.ds(mi * bm, bm), pl.ds(kk * bk, bk)],
            a_ring.at[slot], in_sems.at[slot, 0]).wait()
        pltpu.make_async_copy(
            b_hbm.at[pl.ds(kk * bk, bk), pl.ds(ni * bn, bn)],
            b_ring.at[slot], in_sems.at[slot, 1]).wait()

        acc_ref[...] += jax.lax.dot_general(
            a_ring[slot].astype(jnp.float32),
            b_ring[slot].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return ()

    jax.lax.fori_loop(0, n_k, body, (), unroll=False)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def nbb_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
               bk: int = 512, interpret: bool = False) -> jax.Array:
    """[M, K] @ [K, N] with an explicit 2-slot NBB VMEM ring per operand."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk

    kernel = functools.partial(_nbb_matmul_kernel, bm=bm, bn=bn, bk=bk,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # a stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # b stays in HBM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bm, bk), a.dtype),        # NBB ring: A tiles
            pltpu.VMEM((2, bk, bn), b.dtype),        # NBB ring: B tiles
            pltpu.VMEM((bm, bn), jnp.float32),       # accumulator
            pltpu.SemaphoreType.DMA((2, 2)),         # per-slot, per-operand
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, b)
