"""Pallas paged decode-attention (TPU target, interpret=True on CPU).

The KV history of a sequence lives scattered across fixed-size *pages*
of a shared pool (``serve/kv_cache.py``); a per-row **block table** maps
logical position ``t`` to physical page ``block[b, t // page_size]``.
This kernel attends directly over those scattered pages — the
vLLM-style paged attention, which is the KV-domain analogue of the
paper's zero-copy NBB exchange (DESIGN.md §10): instead of gathering a
sequence's pages into a contiguous per-slot buffer before every decode
step (a copy-in intermediary), the consumer reads through the
indirection table and "swap-in" degenerates to writing an int32 row.

Grid = (B, H, P) with the page index innermost: the Pallas pipeline
keeps two page tiles in flight in VMEM (the familiar two-slot NBB
discipline), and the *block table is a scalar-prefetch operand* — its
entries must be known before the kernel body runs because they feed the
k/v ``index_map`` that steers each page DMA.

Deployment status: the serving path (``layers.attention``'s paged
branch) currently expresses this same block-table access pattern in
jnp — on CPU that reference is the only runnable form, and it is what
keeps token sequences byte-identical to the dense backend.  This
kernel is the TPU lowering of that read path, validated against
``ref.paged_attention_ref`` in interpret mode (tests/
test_kernels_paged.py) and microbenched in benchmarks/bench_kernels.py;
wiring it behind a backend switch is deliberately left until a real
TPU target exists to measure on.

Layout: q [B, T, H, hd]; k/v pages [n_pages, page_size, Hkv, hd]
(one layer's view of the pool).  GQA via the k/v index_map (integer
division of the head index).  Rows are causally masked to their own
true length: q token t sits at absolute position ``lens[b] - T + t``
and attends positions ``<=`` its own.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  page_size: int, n_q: int, softcap: float, scale: float):
    """Grid = (B, H, P); page index innermost (sequential accumulation)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]                                  # true kv extent
    q_pos = length - n_q + jax.lax.broadcasted_iota(
        jnp.int32, (n_q, page_size), 0)
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (n_q, page_size), 1)

    # Page-level skip: pages entirely past the row's extent hold other
    # sequences' (or no) data and must contribute nothing.  Causality
    # makes the same cut (k_first <= q_last == length - 1).
    @pl.when(j * page_size < length)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)         # [n_q, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # [ps, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = k_pos <= q_pos                             # causal AND valid
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # [n_q, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)         # [ps, hd]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block: jax.Array, lens: jax.Array, *,
                    softcap: float = 0.0,
                    interpret: bool = False) -> jax.Array:
    """Attend q over page-scattered KV via a block table.

    q:        [B, T, H, hd] — the T newest tokens of each row (their KV
              already written to the pages; positions lens-T .. lens-1).
    k_pages:  [n_pages, page_size, Hkv, hd] — one layer of the pool.
    v_pages:  same shape.
    block:    [B, P] int32 — page ids per row, position-ordered; entries
              past the row's extent may be stale (they are masked, but
              must stay in [0, n_pages) so the prefetch DMA is safe).
    lens:     [B] int32 — true kv length per row (including the T query
              tokens).  Causal masking is against this, not P*page_size.

    Returns [B, T, H, hd] in q.dtype.
    """
    B, T, H, hd = q.shape
    n_pages, page_size, Hkv, _ = k_pages.shape
    P = block.shape[1]
    assert H % Hkv == 0
    group = H // Hkv

    grid = (B, H, P)

    def q_map(b, h, j, blk, ln):
        return (b, 0, h, 0)

    def kv_map(b, h, j, blk, ln):
        return (blk[b, j], 0, h // group, 0)

    kernel = functools.partial(
        _paged_kernel, page_size=page_size, n_q=T, softcap=softcap,
        scale=hd ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + lens steer the DMA
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, 1, hd), q_map),
            pl.BlockSpec((1, page_size, 1, hd), kv_map),
            pl.BlockSpec((1, page_size, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, T, 1, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((T, 1), jnp.float32),      # running max
            pltpu.VMEM((T, 1), jnp.float32),      # running sum
            pltpu.VMEM((T, hd), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), q.dtype),
        interpret=interpret,
    )(block.astype(jnp.int32), lens.astype(jnp.int32), q, k_pages, v_pages)
