"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth the kernels are validated
against (interpret=True on CPU, real lowering on TPU).  They are written
for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """[M, K] @ [K, N] -> [M, N] with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """Reference GQA attention.

    q: [B, T, H, hd]; k/v: [B, S, Hkv, hd] with H % Hkv == 0.
    causal assumes q positions are S-T..S-1 (suffix of the kv sequence).
    window: sliding-window size (0 = unlimited).
    Returns [B, T, H, hd] in q.dtype.
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, T, Hkv, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    q_pos = jnp.arange(T) + (S - T)
    kv_pos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block: jax.Array,
                        lens: jax.Array, softcap: float = 0.0) -> jax.Array:
    """Reference paged attention: dense gather through the block table.

    q: [B, T, H, hd] — the T newest tokens per row (KV already written;
    q token t sits at absolute position ``lens[b] - T + t``).
    k_pages/v_pages: [n_pages, page_size, Hkv, hd] (one layer's pool
    view); block: [B, P] int32 position-ordered page ids; lens: [B]
    int32 true kv extent per row.  This is the copy-in path the kernel
    deletes: materialize each row's pages contiguously, then attend.
    Returns [B, T, H, hd] in q.dtype.
    """
    B, T, H, hd = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    S = block.shape[1] * page_size
    k = k_pages[block].reshape(B, S, Hkv, hd)      # the dense gather
    v = v_pages[block].reshape(B, S, Hkv, hd)
    g = H // Hkv
    qg = q.reshape(B, T, Hkv, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    q_pos = lens[:, None] - T + jnp.arange(T)[None, :]          # [B, T]
    kv_pos = jnp.arange(S)
    # One comparison covers causality AND the row's true extent:
    # kv_pos <= q_pos <= lens - 1 < S.
    mask = kv_pos[None, None, :] <= q_pos[:, :, None]           # [B, T, S]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # Fully-masked (idle) rows: every score is -1e30, softmax degrades
    # to uniform garbage — zero it so idle rows return 0 like the kernel.
    any_valid = mask.any(axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    """Fused SwiGLU MLP oracle: silu(x@Wg) * (x@Wu) @ Wd."""
    h = jax.nn.silu(jnp.dot(x, w_gate, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    return jnp.dot(h.astype(x.dtype), w_down,
                   preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm_matmul_ref(x: jax.Array, scale: jax.Array, w: jax.Array,
                       eps: float = 1e-6) -> jax.Array:
    """Fused rmsnorm(x) @ W oracle."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
    return jnp.dot(y.astype(x.dtype), w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
