"""Pallas flash attention (TPU target, interpret=True validation on CPU).

Online-softmax blocked attention.  The kv-sequence loop is the innermost
grid dimension, so the Pallas pipeline keeps exactly two kv tiles in
flight in VMEM — the same two-slot NBB discipline as the paper's ring
buffer (DESIGN.md §2): the DMA engine (producer) fills slot ``w mod 2``
while the MXU (consumer) reads slot ``r mod 2``; the grid guarantees the
indices never collide, which is lock-freedom by construction.

Layout: q [B, H, T, hd], k/v [B, Hkv, S, hd] (head-major so each grid
step addresses one head's contiguous tiles).  GQA is expressed through
the k/v index_map (integer division of the head index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.7 names the TPU compiler options TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, seq_k: int, q_offset: int,
                 scale: float):
    """Grid = (B*H, T//block_q, S//block_k); kv index innermost."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Tile-level skip: with causal masking, tiles strictly above the
    # diagonal contribute nothing; with a sliding window, tiles entirely
    # left of the window do not either.
    q_first = qi * block_q + q_offset
    q_last = q_first + block_q - 1
    k_first = ki * block_k
    k_last = k_first + block_k - 1
    run = True
    if causal:
        run = jnp.logical_and(run, k_first <= q_last)
    if window:
        run = jnp.logical_and(run, k_last > q_first - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                     # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows (exp(NEG_INF - NEG_INF) = 1 garbage).
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                     # [bk, hd]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                      # masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: [B, T, H, hd]; k/v: [B, S, Hkv, hd] -> [B, T, H, hd].

    Causal convention matches ref.flash_attention_ref: q rows occupy the
    last T positions of the S-long kv sequence.
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0 and S % block_k == 0
    block_q = min(block_q, T)
    assert T % block_q == 0
    group = H // Hkv

    # head-major layout for contiguous per-head tiles
    qm = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    km = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vm = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)

    grid = (B * H, T // block_q, S // block_k)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return (h // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, seq_k=S, q_offset=S - T,
        scale=hd ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qm, km, vm)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Differentiable wrapper: kernel forward, flash-recompute backward.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_trainable(q, k, v, causal=True, window=0, softcap=0.0,
                              block_q=128, block_k=128, interpret=False):
    """flash_attention with a VJP.  The backward pass recomputes attention
    from the residuals (q, k, v) — the standard flash-attention recompute
    strategy — expressed in jnp so XLA fuses it; the forward stays on the
    Pallas kernel."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, block_q=block_q,
                           block_k=block_k, interpret=interpret)


def _fa_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, block_q, block_k, interpret, res, g):
    from repro.kernels import ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
