"""Model-checking scenarios over the lock-free core.

Each scenario is a factory returning a fresh :class:`repro.core.interleave.World`:
a small, *bounded* cast of tasks exercising one structure through its
instrumented yield points, plus the invariants that convict a bad
interleaving — a linearizability check against the structure's
sequential spec (:mod:`repro.checker.specs`), the torn-read detector
(:mod:`repro.checker.detectors`), and scenario-specific assertions
(exactly-one-winner, committed-prefix-only delivery, ...).

Design rules every scenario follows:

* **Tasks are finite under EVERY schedule.**  Consumers make a fixed
  number of poll attempts rather than spinning until satisfied — an
  unfair schedule must not be able to livelock a task.  *Completeness*
  (every accepted item eventually delivered) is then asserted in the
  ``check`` hook, which runs disarmed after all tasks finish and can
  drain sequentially.
* **All task-visible state is in the fingerprint.**  Results are routed
  through the shared :class:`repro.checker.lin.Recorder` (or shared
  lists), and the fingerprint covers structure internals + recorder
  events + flags, so DFS state-pruning is sound.
* **Two scenarios are deliberately broken** (``expect="violation"``):
  ``broken_ring`` validates the torn-read detector's sensitivity, and
  ``legacy_statecell_compaction`` preserves the journal-compaction
  lost-update race this checker found in the original ``StateCell``
  (fixed in ``repro.core.states``; the minimized schedule lives in
  ``tests/schedules/`` as a regression).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import faults, nbb, states, transport
from repro.core import interleave as il
from repro.core.bitset import HostBitset
from repro.core.host_queue import MpscQueue
from repro.core.nbb import HostNBB
from repro.core.refcount import RefCountArray
from repro.checker import detectors, specs
from repro.checker.lin import MISSING, Recorder, assert_linearizable


# ---------------------------------------------------------------------------
# Fingerprint helpers: hashable snapshots of structure internals.
# ---------------------------------------------------------------------------
def ring_fp(r: HostNBB) -> Tuple:
    return (r._uc, r._ac, tuple(r._slots))


def mpsc_fp(q: MpscQueue) -> Tuple:
    return (tuple(ring_fp(r) for r in q._rings), q._cursor)


def refcount_fp(rc: RefCountArray) -> Tuple:
    return (tuple(len(d) for d in rc._refs), tuple(sorted(rc._claiming)))


def bitset_fp(b: HostBitset) -> Tuple:
    return tuple(sorted(b._claims))


def cell_fp(c: states.StateCell) -> Tuple:
    # Seqs come from a process-global counter, so they differ across DFS
    # re-executions of the same logical state; rank them journal-locally
    # to keep fingerprints execution-stable (pruning soundness only needs
    # relative order + identity of each entry's verdict bits).
    base = c._base
    journal = list(c._journal)
    rank = {s: i for i, s in enumerate(sorted(e[0] for e in journal))}
    folded = {id(e) for e in base[1]}
    return (base[0],
            tuple((rank[e[0]], e[1], e[2], e[3], id(e) in folded)
                  for e in journal),
            tuple(sorted(c._cguard)))


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    make_world: Callable[[], il.World]
    expect: str                       # "pass" | "violation"
    structure: str                    # which primitive it validates
    #: suggested exhaustive budget (max_executions) for a full explore
    explore_budget: int = 4000
    max_steps: int = 400


SCENARIOS: Dict[str, Scenario] = {}


def _register(name: str, expect: str, structure: str,
              explore_budget: int = 4000, max_steps: int = 400):
    def deco(fn: Callable[[], il.World]) -> Callable[[], il.World]:
        SCENARIOS[name] = Scenario(name=name, make_world=fn, expect=expect,
                                   structure=structure,
                                   explore_budget=explore_budget,
                                   max_steps=max_steps)
        return fn
    return deco


def get(name: str) -> Scenario:
    return SCENARIOS[name]


# ---------------------------------------------------------------------------
# SPSC ring: scalar protocol.
# ---------------------------------------------------------------------------
@_register("spsc_scalar", "pass", "HostNBB")
def spsc_scalar() -> il.World:
    """1 producer x 3 sends, 1 consumer x 4 bounded polls on a 2-slot
    ring: every counter announce/commit interleaving of the scalar
    protocol.  Lin vs the strict SPSC spec + torn-read detection +
    completeness (accepted items all delivered, in order)."""
    ring = HostNBB(2)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer() -> None:
        for item in (10, 11, 12):
            opid = rec.invoke("p", "send", item)
            rec.respond(opid, specs.status_class(ring.insert_item(item)))

    def consumer() -> None:
        for _ in range(4):
            opid = rec.invoke("c", "recv")
            st, got = ring.read_item()
            rec.respond(opid, (specs.status_class(st), got))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "spsc_scalar")
        # Completeness: what the consumer missed is still in the ring.
        leftover = ring.drain()
        for item in leftover:
            opid = rec.invoke("main", "recv")
            rec.respond(opid, ("OK", item))
        result = assert_linearizable(rec, specs.SpscRingSpec(2),
                                     "spsc_scalar")
        accepted = [o.args[0] for o in result.ops
                    if o.op == "send" and o.result == "OK"]
        delivered = [o.result[1] for o in result.ops
                     if o.op == "recv" and o.result[0] == "OK"]
        assert delivered == accepted, (delivered, accepted)

    world.tasks = [("p", producer), ("c", consumer)]
    world.fingerprint = lambda: (ring_fp(ring), rec.fingerprint())
    world.check = check
    return world


@_register("spsc_burst", "pass", "HostNBB", explore_budget=6000)
def spsc_burst() -> il.World:
    """Packet mode: span reservations racing span drains on a 3-slot
    ring (wrap-around covered: the second burst wraps)."""
    ring = HostNBB(3)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer() -> None:
        for vals in ((0, 1), (2, 3)):
            opid = rec.invoke("p", "send_burst", vals)
            st, m = ring.send_burst(list(vals))
            rec.respond(opid, (specs.status_class(st), m))

    def consumer() -> None:
        for _ in range(3):
            opid = rec.invoke("c", "drain", 2)
            rec.respond(opid, tuple(ring.drain_burst(2)))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "spsc_burst")
        leftover = ring.drain_burst()
        if True:
            opid = rec.invoke("main", "drain", None)
            rec.respond(opid, tuple(leftover))
        result = assert_linearizable(rec, specs.SpscRingSpec(3),
                                     "spsc_burst")
        accepted = []
        for o in result.ops:
            if o.op == "send_burst":
                accepted.extend(o.args[0][:o.result[1]])
        delivered = [v for o in result.ops if o.op == "drain"
                     for v in o.result]
        assert delivered == accepted, (delivered, accepted)

    world.tasks = [("p", producer), ("c", consumer)]
    world.fingerprint = lambda: (ring_fp(ring), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# MPSC fan-in.
# ---------------------------------------------------------------------------
@_register("mpsc_fanin", "pass", "MpscQueue", explore_budget=12000)
def mpsc_fanin() -> il.World:
    """2 producers x 2 sends into private rings, consumer round-robin
    scan x 5 bounded polls — the issue's canonical small bound."""
    q = MpscQueue(2, capacity_per_producer=2)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer(pid: int) -> Callable[[], None]:
        def fn() -> None:
            for k in range(2):
                item = 10 * pid + k
                opid = rec.invoke(f"p{pid}", "send", pid, item)
                rec.respond(opid,
                            specs.status_class(q.insert_item(pid, item)))
        return fn

    def consumer() -> None:
        for _ in range(5):
            opid = rec.invoke("c", "recv")
            st, got = q.read_item()
            rec.respond(opid, (specs.status_class(st), got))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "mpsc_fanin")
        while True:
            st, got = q.read_item()
            if st != nbb.OK:
                break
            opid = rec.invoke("main", "recv")
            rec.respond(opid, ("OK", got))
        result = assert_linearizable(rec, specs.MpscSpec(2, 2),
                                     "mpsc_fanin")
        delivered = [o.result[1] for o in result.ops
                     if o.op == "recv" and o.result[0] == "OK"]
        assert sorted(delivered) == [0, 1, 10, 11], delivered

    world.tasks = [("p0", producer(0)), ("p1", producer(1)),
                   ("c", consumer)]
    world.fingerprint = lambda: (mpsc_fp(q), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# Allocators: bitset and refcount array.
# ---------------------------------------------------------------------------
@_register("bitset_hammer", "pass", "HostBitset")
def bitset_hammer() -> il.World:
    """3 claimers hammer 2 slots: every slot claimed at most once, the
    loser's None refusal admitted weakly (scan allocator)."""
    bs = HostBitset(2)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def claimer(name: str) -> Callable[[], None]:
        def fn() -> None:
            opid = rec.invoke(name, "try_claim")
            rec.respond(opid, bs.try_claim(owner=name))
        return fn

    def check() -> None:
        result = assert_linearizable(rec, specs.BitsetSpec(2),
                                     "bitset_hammer")
        wins = [o.result for o in result.ops if o.result is not None]
        assert len(wins) == len(set(wins)), wins     # distinct slots
        assert bs.count() == len(wins), (bs.count(), wins)

    world.tasks = [("a", claimer("a")), ("b", claimer("b")),
                   ("d", claimer("d"))]
    world.fingerprint = lambda: (bitset_fp(bs), rec.fingerprint())
    world.check = check
    return world


@_register("refcount_claim", "pass", "RefCountArray")
def refcount_claim() -> il.World:
    """3 claimers race claim-from-zero on 2 slots — the guard must
    yield at most one winner per slot."""
    rc = RefCountArray(2)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def claimer(name: str) -> Callable[[], None]:
        def fn() -> None:
            opid = rec.invoke(name, "try_claim")
            rec.respond(opid, rc.try_claim())
        return fn

    def check() -> None:
        result = assert_linearizable(rec, specs.RefCountSpec(2),
                                     "refcount_claim")
        wins = [o.result for o in result.ops if o.result is not None]
        assert len(wins) == len(set(wins)), wins
        assert rc.count() == len(wins)
        for i in range(2):
            assert rc.refcount(i) <= 1, rc.refcount(i)

    world.tasks = [("a", claimer("a")), ("b", claimer("b")),
                   ("d", claimer("d"))]
    world.fingerprint = lambda: (refcount_fp(rc), rec.fingerprint())
    world.check = check
    return world


@_register("refcount_share", "pass", "RefCountArray")
def refcount_share() -> il.World:
    """incref/decref churn on a held slot racing a thief's
    claim-from-zero: the count never passes through zero, so the thief
    must never win (the storm test, deterministically)."""
    rc = RefCountArray(1)
    assert rc.try_claim() == 0                    # disarmed setup: held
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def churner(name: str) -> Callable[[], None]:
        def fn() -> None:
            for _ in range(2):
                opid = rec.invoke(name, "incref", 0)
                rc.incref(0)
                rec.respond(opid, MISSING)
                opid = rec.invoke(name, "decref", 0)
                rc.decref(0)
                rec.respond(opid, MISSING)
        return fn

    def thief() -> None:
        for _ in range(2):
            opid = rec.invoke("t", "claim_specific", 0)
            rec.respond(opid, rc.claim_specific(0))

    def check() -> None:
        ops = rec.ops()
        stolen = [o for o in ops
                  if o.op == "claim_specific" and o.result is True]
        assert not stolen, "claim-from-zero won while slot was held"
        assert rc.refcount(0) == 1, rc.refcount(0)

    world.tasks = [("x", churner("x")), ("y", churner("y")), ("t", thief)]
    world.fingerprint = lambda: (refcount_fp(rc), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# StateCell CAS consensus + compaction.
# ---------------------------------------------------------------------------
def _prefill(cell, n_ops: int) -> None:
    """Disarmed setup: walk REQUEST cycles to grow the journal."""
    edges = [(states.REQUEST_FREE, states.REQUEST_VALID),
             (states.REQUEST_VALID, states.REQUEST_RECEIVED),
             (states.REQUEST_RECEIVED, states.REQUEST_COMPLETED),
             (states.REQUEST_COMPLETED, states.REQUEST_FREE)]
    for k in range(n_ops):
        e, n = edges[k % 4]
        assert cell.cas(e, n)


@_register("statecell_cas", "pass", "StateCell")
def statecell_cas() -> il.World:
    """The OP_TRANSITIONS consensus: complete vs cancel racing through
    one CAS — exactly one terminal wins."""
    cell = states.op_cell("race")
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def proposer(name: str, new: str) -> Callable[[], None]:
        def fn() -> None:
            opid = rec.invoke(name, "cas", states.OP_PENDING, new)
            rec.respond(opid, cell.cas(states.OP_PENDING, new))
        return fn

    def check() -> None:
        opid = rec.invoke("main", "read")
        rec.respond(opid, cell.state)
        result = assert_linearizable(
            rec, specs.FsmSpec(states.OP_TRANSITIONS, states.OP_PENDING),
            "statecell_cas")
        wins = [o for o in result.ops if o.op == "cas" and o.result]
        assert len(wins) == 1, result.explain()

    world.tasks = [("done", proposer("done", states.OP_COMPLETED)),
                   ("kill", proposer("kill", states.OP_CANCELLED))]
    world.fingerprint = lambda: (cell_fp(cell), rec.fingerprint())
    world.check = check
    return world


@_register("statecell_compaction", "pass", "StateCell",
           explore_budget=20000, max_steps=600)
def statecell_compaction() -> il.World:
    """Two dependent CAS chains racing a journal compaction at the
    threshold — the exact window where the legacy cell lost updates.
    The resolved-prefix protocol must keep every reported win."""
    cell = states.StateCell(states.REQUEST_TRANSITIONS,
                            states.REQUEST_FREE, "compact", compact_at=4)
    _prefill(cell, 4)                 # journal at threshold, state FREE
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def proposer(name: str, edge: Tuple[str, str]) -> Callable[[], None]:
        def fn() -> None:
            opid = rec.invoke(name, "cas", *edge)
            rec.respond(opid, cell.cas(*edge))
        return fn

    def check() -> None:
        opid = rec.invoke("main", "read")
        rec.respond(opid, cell.state)
        assert_linearizable(
            rec, specs.FsmSpec(states.REQUEST_TRANSITIONS,
                               states.REQUEST_FREE),
            "statecell_compaction")

    world.tasks = [
        ("a", proposer("a", (states.REQUEST_FREE, states.REQUEST_VALID))),
        ("b", proposer("b", (states.REQUEST_VALID,
                             states.REQUEST_RECEIVED))),
    ]
    world.fingerprint = lambda: (cell_fp(cell), rec.fingerprint())
    world.check = check
    return world


class LegacyStateCell:
    """The original StateCell compaction algorithm, preserved verbatim
    (modulo explicit yield points) as the checker's found-bug exhibit:
    ``cas`` folds the journal and then replaces base and journal with
    TWO attribute stores — a competitor's winning proposal appended
    between the fold and the journal replacement is erased, so the cell
    regresses past a reported win.  ``repro.core.states.StateCell``
    fixes this with the resolved-prefix single-store protocol."""

    def __init__(self, table, initial: str, compact_at: int = 4):
        self._table = table
        self._base = initial
        self._journal: list = []
        self._compact_at = compact_at

    def _fold(self):
        state = self._base
        winners = set()
        for seq, expected, new in self._journal:
            if expected == state and new in self._table[state]:
                state = new
                winners.add(seq)
        return state, winners

    @property
    def state(self) -> str:
        return self._fold()[0]

    def cas(self, expected: str, new: str) -> bool:
        if new not in self._table.get(expected, frozenset()):
            raise states.IllegalTransition(f"{expected} -> {new}")
        seq = next(states._seq)
        il.yield_point("legacy.append", id(self))
        self._journal.append((seq, expected, new))
        il.yield_point("legacy.fold", id(self))
        _, winners = self._fold()
        won = seq in winners
        if len(self._journal) > self._compact_at:
            state, _ = self._fold()
            il.yield_point("legacy.swap.base", id(self))
            self._base = state            # two stores: the fatal window
            il.yield_point("legacy.swap.journal", id(self))
            self._journal = []
        return won


@_register("legacy_statecell_compaction", "violation", "StateCell",
           explore_budget=20000, max_steps=600)
def legacy_statecell_compaction() -> il.World:
    """The counterexample scenario: same cast as ``statecell_compaction``
    against the legacy algorithm.  ``explore`` finds a schedule where a
    reported win evaporates; the minimized schedule is committed under
    ``tests/schedules/`` as a regression."""
    cell = LegacyStateCell(states.REQUEST_TRANSITIONS,
                           states.REQUEST_FREE, compact_at=4)
    edges = [(states.REQUEST_FREE, states.REQUEST_VALID),
             (states.REQUEST_VALID, states.REQUEST_RECEIVED),
             (states.REQUEST_RECEIVED, states.REQUEST_COMPLETED),
             (states.REQUEST_COMPLETED, states.REQUEST_FREE)]
    for k in range(4):                # journal at threshold, state FREE
        assert cell.cas(*edges[k])
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def proposer(name: str, edge: Tuple[str, str]) -> Callable[[], None]:
        def fn() -> None:
            opid = rec.invoke(name, "cas", *edge)
            rec.respond(opid, cell.cas(*edge))
        return fn

    def check() -> None:
        opid = rec.invoke("main", "read")
        rec.respond(opid, cell.state)
        assert_linearizable(
            rec, specs.FsmSpec(states.REQUEST_TRANSITIONS,
                               states.REQUEST_FREE),
            "legacy_statecell_compaction")

    world.tasks = [
        ("a", proposer("a", (states.REQUEST_FREE, states.REQUEST_VALID))),
        ("b", proposer("b", (states.REQUEST_VALID,
                             states.REQUEST_RECEIVED))),
    ]
    world.fingerprint = lambda: (
        (cell._base, tuple(cell._journal)), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# OpHandle: exactly one terminal state.
# ---------------------------------------------------------------------------
@_register("ophandle_cancel", "pass", "OpHandle", max_steps=600)
def ophandle_cancel() -> il.World:
    """test() racing cancel() on a recv handle over a 1-item ring: the
    PENDING -> COMPLETED|CANCELLED CAS admits exactly one terminal, and
    a committed queue op that loses to cancel parks its item in
    ``late_result`` instead of losing it."""
    ring = HostNBB(2)
    assert ring.insert_item(77) == nbb.OK         # disarmed preload
    h = transport.OpHandle(ring.read_item, name="recv")
    results: Dict[str, object] = {}
    world = il.World(tasks=[], fingerprint=None, check=None)

    def tester() -> None:
        results["test"] = h.test()

    def canceller() -> None:
        results["cancel"] = h.cancel()

    def check() -> None:
        assert h.done
        assert h.completed != h.cancelled          # exactly one terminal
        if h.completed:
            assert results.get("test") is True
            assert results.get("cancel") is False
            assert h.result == 77 and len(ring) == 0
        else:
            assert results.get("cancel") is True
            assert results.get("test") in (False, None)
            if h.attempted_ok:                     # op landed, cancel won
                assert h.late_result == 77         # parked, not lost
                assert len(ring) == 0
            else:
                assert len(ring) == 1              # item untouched

    world.tasks = [("test", tester), ("cancel", canceller)]
    world.fingerprint = lambda: (
        ring_fp(ring), cell_fp(h._fsm),
        tuple(sorted(results.items())), h.attempted_ok)
    world.check = check
    return world


# ---------------------------------------------------------------------------
# PriorityTransport scan order.
# ---------------------------------------------------------------------------
@_register("priority_scan", "pass", "PriorityTransport",
           explore_budget=8000)
def priority_scan() -> il.World:
    """Preloaded urgent (class 0) and bulk (class 1) items with a
    producer topping up class 0 mid-scan: per-class FIFO holds
    (linearizability) and a preloaded bulk item is never delivered
    before the preloaded urgent one (the scan's priority guarantee for
    items committed before the scan began)."""
    pt = transport.PriorityTransport([HostNBB(2), HostNBB(2)])
    rec = Recorder()
    # Disarmed preload, recorded so the spec sees it.
    for cls, item in ((0, "a0"), (1, "b0")):
        opid = rec.invoke("setup", "send", cls, item)
        rec.respond(opid, specs.status_class(pt.send_to(item, cls)))
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer() -> None:
        opid = rec.invoke("p", "send", 0, "a1")
        rec.respond(opid, specs.status_class(pt.send_to("a1", 0)))

    def consumer() -> None:
        for _ in range(3):
            opid = rec.invoke("c", "recv")
            st, got = pt.try_recv()
            rec.respond(opid, (specs.status_class(st), got))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "priority_scan")
        for item in pt.drain():
            opid = rec.invoke("main", "recv")
            rec.respond(opid, ("OK", item))
        result = assert_linearizable(rec, specs.PriorityFanSpec(2, 2),
                                     "priority_scan")
        delivered = [o.result[1] for o in result.ops
                     if o.op == "recv" and o.result[0] == "OK"]
        assert sorted(delivered) == ["a0", "a1", "b0"], delivered
        assert delivered.index("a0") < delivered.index("b0"), delivered

    world.tasks = [("p", producer), ("c", consumer)]
    world.fingerprint = lambda: (
        tuple(ring_fp(r) for r in pt.classes), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# Fault composition: torn-span recovery model-checked (PR-8 paths).
# ---------------------------------------------------------------------------
@_register("torn_span_recovery", "pass", "HostNBB+FaultPlan",
           explore_budget=30000, max_steps=600)
def torn_span_recovery() -> il.World:
    """A producer dies mid-span-reservation (``transport.stall`` via
    FaultPlan) at every reachable interleaving point; a consumer drains
    concurrently; a recovery task rolls the ring back (``recover_ring``)
    once the producer is known dead and resumes service.  Invariants:
    the consumer only ever sees the committed prefix — never a slot of
    the stalled span — and post-recovery sends are delivered."""
    ring = HostNBB(4)
    plan = faults.FaultPlan(
        [faults.FaultRule(site="transport.stall", nth=2)], name="stall")
    ft = transport.FaultyTransport(ring, plan, name="spsc")
    rec = Recorder()
    flags: Dict[str, bool] = {"dead": False, "recovered": False,
                              "resent": False}
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer() -> None:
        opid = rec.invoke("p", "send_burst", (0, 1))
        st, m = ft.send_burst([0, 1])             # commits: the prefix
        rec.respond(opid, (specs.status_class(st), m))
        opid = rec.invoke("p", "send_burst", (2, 3))
        try:
            ft.send_burst([2, 3])                 # stalls: announced, dead
        except faults.InjectedFault:
            flags["dead"] = True
            rec.respond(opid, MISSING)

    def consumer() -> None:
        for _ in range(4):
            opid = rec.invoke("c", "recv")
            st, got = ring.read_item()
            rec.respond(opid, (specs.status_class(st), got))

    def reaper() -> None:
        for _ in range(4):
            il.yield_point("reaper.poll", None)
            if flags["dead"]:
                flags["recovered"] = faults.recover_ring(ring)
                il.yield_point("reaper.resend", None)
                st = ring.insert_item(9)          # new producer-owner
                flags["resent"] = st == nbb.OK
                return

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "torn_span_recovery")
        delivered = [o.result[1] for o in rec.ops()
                     if o.op == "recv" and o.result is not MISSING
                     and o.result[0] == "OK"]
        delivered += ring.drain()                  # disarmed completeness
        # Committed-prefix-only delivery: the stalled span (2, 3) must
        # never surface, whole committed prefix must, in order.
        assert not any(v in (2, 3) for v in delivered), delivered
        expect = [0, 1] + ([9] if flags["resent"] else [])
        assert delivered == expect, (delivered, flags)
        if flags["dead"] and flags["recovered"]:
            assert not ring._uc & 1                # rollback landed

    world.tasks = [("p", producer), ("c", consumer), ("r", reaper)]
    world.fingerprint = lambda: (
        ring_fp(ring), rec.fingerprint(), tuple(sorted(flags.items())))
    world.check = check
    return world


@_register("mpsc_dead_producer", "pass", "MpscQueue+FaultPlan",
           explore_budget=20000, max_steps=600)
def mpsc_dead_producer() -> il.World:
    """One producer of an MPSC fan-in dies mid-span; siblings and the
    round-robin consumer must be unaffected (the stalled ring's span is
    invisible, other rings drain normally)."""
    q = MpscQueue(2, capacity_per_producer=4)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def dying_producer() -> None:
        opid = rec.invoke("p0", "send", 0, 100)
        rec.respond(opid, specs.status_class(q.insert_item(0, 100)))
        il.yield_point("p0.stall", None)
        faults.stall_mid_burst(q.producer(0), [101, 102])  # dies here

    def live_producer() -> None:
        for item in (200, 201):
            opid = rec.invoke("p1", "send", 1, item)
            rec.respond(opid, specs.status_class(q.insert_item(1, item)))

    def consumer() -> None:
        for _ in range(4):
            opid = rec.invoke("c", "recv")
            st, got = q.read_item()
            rec.respond(opid, (specs.status_class(st), got))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "mpsc_dead_producer")
        delivered = [o.result[1] for o in rec.ops()
                     if o.op == "recv" and o.result[0] == "OK"]
        delivered += q.drain_burst()
        assert not any(v in (101, 102) for v in delivered), delivered
        assert [v for v in delivered if v >= 200] == [200, 201], delivered
        assert 100 in delivered, delivered

    world.tasks = [("p0", dying_producer), ("p1", live_producer),
                   ("c", consumer)]
    world.fingerprint = lambda: (mpsc_fp(q), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# Detector sensitivity: a deliberately broken ring must be convicted.
# ---------------------------------------------------------------------------
class BrokenNBB(HostNBB):
    """HostNBB with the commit store hoisted ABOVE the slot write — the
    textbook epoch-protocol bug.  A consumer scheduled between commit
    and write reads a slot the producer is still writing."""

    def insert_item(self, item) -> int:           # type: ignore[override]
        il.yield_point("nbb.send.load", id(self))
        uc = self._uc
        ac = self._ac
        if (uc // 2) - (ac // 2) >= self._n:
            return nbb.BUFFER_FULL_BUT_CONSUMER_READING if ac & 1 \
                else nbb.BUFFER_FULL
        il.yield_point("nbb.send.commit", id(self))
        self._uc = uc + 2                         # BUG: commit first ...
        il.yield_point("nbb.send.slot", (id(self), (uc // 2) % self._n))
        self._slots[(uc // 2) % self._n] = item   # ... write after
        return nbb.OK


@_register("broken_ring", "violation", "detector-sensitivity")
def broken_ring() -> il.World:
    """The torn-read detector must convict the commit-before-write ring
    (a schedule exists where the consumer reads the unwritten slot)."""
    ring = BrokenNBB(2)
    rec = Recorder()
    world = il.World(tasks=[], fingerprint=None, check=None)

    def producer() -> None:
        for item in (5, 6):
            opid = rec.invoke("p", "send", item)
            rec.respond(opid, specs.status_class(ring.insert_item(item)))

    def consumer() -> None:
        for _ in range(3):
            opid = rec.invoke("c", "recv")
            st, got = ring.read_item()
            rec.respond(opid, (specs.status_class(st), got))

    def check() -> None:
        detectors.assert_no_torn_reads(world.trace, "broken_ring")

    world.tasks = [("p", producer), ("c", consumer)]
    world.fingerprint = lambda: (ring_fp(ring), rec.fingerprint())
    world.check = check
    return world


# ---------------------------------------------------------------------------
# Convenience drivers.
# ---------------------------------------------------------------------------
def explore_scenario(name: str,
                     max_executions: Optional[int] = None,
                     max_steps: Optional[int] = None) -> il.ExploreResult:
    s = get(name)
    return il.explore(
        s.make_world,
        max_executions=max_executions or s.explore_budget,
        max_steps=max_steps or s.max_steps)


def fuzz_scenario(name: str, seed: int = 0, runs: int = 50,
                  max_steps: Optional[int] = None) -> il.FuzzResult:
    s = get(name)
    return il.fuzz(s.make_world, seed=seed, runs=runs,
                   max_steps=max_steps or s.max_steps)


def replay(name: str, schedule, strict: bool = False) -> il.RunResult:
    s = get(name)
    return il.run_schedule(s.make_world, schedule,
                           max_steps=s.max_steps, strict=strict)
