"""Trace detectors for the NBB epoch protocol: torn reads / happens-before.

The scheduler's yield trace records each shared access twice over, in
effect: a task *parks* at a site immediately BEFORE performing the
access, and the access then executes at the start of the task's next
scheduled segment — i.e. just before the task's NEXT trace event.  So
every instrumented access owns an interval in trace positions::

    [event index where the task parked,  index of the task's next event]

during which the access is pending-or-executing.

The NBB Safety property (paper §3: "a successful read never observes a
partially-written slot") is slot disjointness: the producer's write to
slot ``i`` and the consumer's read of slot ``i`` must never be in
flight at the same time — the epoch counters (odd = in-flight) are
precisely the mechanism that keeps the consumer from addressing a slot
before the write's commit store lands.  In interval terms: a write
access to ``(ring, i)`` and a read access to ``(ring, i)`` with
overlapping intervals is a happens-before violation (a torn read in a
memory model with non-atomic slot stores).

Under the correct protocol no overlap can occur: the consumer only
computes a readable index from a committed update count, and the
commit store executes strictly after the write interval closes.  The
detector's sensitivity is validated by the ``broken_ring`` scenario
(commit store before slot write), which it must convict.
"""
from __future__ import annotations

import dataclasses
from typing import Any, FrozenSet, List, Sequence, Tuple

#: Sites whose pending access WRITES ring slots, with their span decoder.
_WRITE_SITES = frozenset({"nbb.send.slot", "nbb.burst.copy"})
#: Sites whose pending access READS ring slots.
_READ_SITES = frozenset({"nbb.recv.slot", "nbb.drain.copy"})


def _span(site: str, info: Any) -> Tuple[int, FrozenSet[int]]:
    """(ring id, slot indices) touched by the access parked at ``site``."""
    if site in ("nbb.send.slot", "nbb.recv.slot"):
        ring, idx = info
        return ring, frozenset((idx,))
    ring, start, m, n = info                     # burst copy span, may wrap
    return ring, frozenset((start + j) % n for j in range(m))


@dataclasses.dataclass(frozen=True)
class TornRead:
    ring: int
    slots: Tuple[int, ...]
    writer_task: int
    reader_task: int
    writer_event: int            # trace index where the write parked
    reader_event: int

    def __str__(self) -> str:
        return (f"torn read: task {self.reader_task} read slot(s) "
                f"{list(self.slots)} of ring {self.ring:#x} while task "
                f"{self.writer_task}'s write was in flight "
                f"(write parked at trace[{self.writer_event}], "
                f"read parked at trace[{self.reader_event}])")


class TornReadDetected(AssertionError):
    """Raised by scenario checks when the detector finds a violation."""


def find_torn_reads(trace: Sequence[Tuple[int, str, Any]]) -> List[TornRead]:
    """All same-slot write/read interval overlaps in a yield trace."""
    n = len(trace)
    # next_own[k] = index of the same task's next event (n when final:
    # instrumented ring accesses are always followed by a commit/ack
    # park, so a ring access interval never actually reaches n).
    next_own = [n] * n
    last: dict = {}
    for k in range(n - 1, -1, -1):
        tid = trace[k][0]
        next_own[k] = last.get(tid, n)
        last[tid] = k

    writes: List[Tuple[int, FrozenSet[int], int, int, int]] = []
    reads: List[Tuple[int, FrozenSet[int], int, int, int]] = []
    for k, (tid, site, info) in enumerate(trace):
        if site in _WRITE_SITES:
            ring, slots = _span(site, info)
            writes.append((ring, slots, tid, k, next_own[k]))
        elif site in _READ_SITES:
            ring, slots = _span(site, info)
            reads.append((ring, slots, tid, k, next_own[k]))

    out: List[TornRead] = []
    for w_ring, w_slots, w_tid, w_beg, w_end in writes:
        for r_ring, r_slots, r_tid, r_beg, r_end in reads:
            if w_ring != r_ring or w_tid == r_tid:
                continue
            if w_beg < r_end and r_beg < w_end:          # intervals overlap
                hit = w_slots & r_slots
                if hit:
                    out.append(TornRead(
                        ring=w_ring, slots=tuple(sorted(hit)),
                        writer_task=w_tid, reader_task=r_tid,
                        writer_event=w_beg, reader_event=r_beg))
    return out


def assert_no_torn_reads(trace: Sequence[Tuple[int, str, Any]],
                         label: str = "") -> None:
    """The form scenario ``check`` hooks use."""
    found = find_torn_reads(trace)
    if found:
        raise TornReadDetected(
            f"{label}: {len(found)} torn read(s); first: {found[0]}")
