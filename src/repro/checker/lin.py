"""Linearizability checking (Wing & Gong DFS) over recorded histories.

A scenario run under :mod:`repro.core.interleave` records each structure
operation as an *invocation* / *response* event pair in a
:class:`Recorder`.  Because exactly one task runs between yield points,
appending an event is atomic and the global event order is the real-time
order of the execution: operation A precedes operation B iff A's
response event lands before B's invocation event.

:func:`check_history` then searches for a *linearization* — a total
order of the operations, consistent with that real-time order, that a
pure sequential specification (:mod:`repro.checker.specs`) accepts with
the observed results.  The search is the classic Wing & Gong DFS: at
each step any not-yet-linearized operation whose invocation precedes
every pending response is a candidate; the spec is asked what results
it could produce in the current abstract state; matching results
advance the state, and the (linearized-set, state) pairs are memoized
so an abstract state reached twice is explored once.

Incomplete operations (an invocation with no response — a task that
died mid-call, e.g. under fault injection) may either take effect with
any result, or never take effect at all; the DFS explores both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, List, Optional, Tuple

#: Result sentinel: "the caller never observed a result — accept any".
MISSING = ("__missing__",)


@dataclasses.dataclass(frozen=True)
class OpRecord:
    op: str
    args: Tuple[Any, ...]
    result: Any                 # MISSING when pending / unobserved
    inv: int                    # invocation event index
    res: Optional[int]          # response event index; None = pending
    task: str = ""


class Recorder:
    """Append-only invocation/response event log for one execution.

    Usage inside a scenario task::

        opid = rec.invoke("p0", "send", item)
        status = ring.insert_item(item)        # yield points fire inside
        rec.respond(opid, specs.status_class(status))

    ``events`` is deliberately part of every scenario's fingerprint:
    routing task-local results through it is what keeps DFS
    state-pruning sound (two executions only share a future if they
    also recorded the same history so far).
    """

    def __init__(self) -> None:
        self.events: List[Tuple[str, int, Any, Any]] = []
        self._next = 0

    def invoke(self, task: str, op: str, *args: Any) -> int:
        opid = self._next
        self._next += 1
        self.events.append(("inv", opid, (task, op, args), None))
        return opid

    def respond(self, opid: int, result: Any) -> None:
        self.events.append(("res", opid, None, result))

    def fingerprint(self) -> Tuple:
        return tuple(self.events)

    def ops(self) -> List[OpRecord]:
        inv: dict = {}
        res: dict = {}
        for i, (kind, opid, meta, result) in enumerate(self.events):
            if kind == "inv":
                inv[opid] = (i, meta)
            else:
                res[opid] = (i, result)
        out = []
        for opid in sorted(inv):
            i, (task, op, args) = inv[opid]
            if opid in res:
                j, result = res[opid]
            else:
                j, result = None, MISSING
            out.append(OpRecord(op=op, args=tuple(args), result=result,
                                inv=i, res=j, task=task))
        return out


@dataclasses.dataclass
class LinResult:
    ok: bool
    linearization: Optional[Tuple[int, ...]]   # op indices in linear order
    states_explored: int
    ops: List[OpRecord]

    def explain(self) -> str:
        if self.ok:
            order = " -> ".join(
                f"{self.ops[k].task}:{self.ops[k].op}{self.ops[k].args}"
                f"={self.ops[k].result}"
                for k in (self.linearization or ()))
            return f"linearizable: {order or '(empty history)'}"
        lines = ["NOT linearizable; history:"]
        for k, o in enumerate(self.ops):
            end = "pending" if o.res is None else str(o.res)
            lines.append(f"  [{k}] {o.task}: {o.op}{o.args} = {o.result!r} "
                         f"(inv {o.inv}, res {end})")
        return "\n".join(lines)


class LinearizabilityViolation(AssertionError):
    """Raised by scenario checks when no linearization exists."""


def _results_match(spec_result: Any, actual: Any) -> bool:
    return actual == MISSING or spec_result == actual


def check_history(ops: List[OpRecord], spec: Any,
                  max_states: int = 500_000) -> LinResult:
    """Wing & Gong DFS.  ``spec`` provides ``init() -> state`` and
    ``apply(state, op, args) -> iterable[(state', result)]`` with
    hashable states.  Raises ``RuntimeError`` past ``max_states`` so a
    spec bug cannot hang the suite."""
    n = len(ops)
    completed_mask = 0
    for k, o in enumerate(ops):
        if o.res is not None:
            completed_mask |= 1 << k
    seen: set = set()
    explored = 0
    path: List[int] = []

    def dfs(mask: int, state: Any) -> bool:
        nonlocal explored
        if mask & completed_mask == completed_mask:
            return True                 # pending ops may dangle forever
        key = (mask, state)
        if key in seen:
            return False
        seen.add(key)
        explored += 1
        if explored > max_states:
            raise RuntimeError(
                f"linearizability search exceeded {max_states} states")
        min_res = min((o.res for k, o in enumerate(ops)
                       if not (mask >> k) & 1 and o.res is not None),
                      default=None)
        for k, o in enumerate(ops):
            if (mask >> k) & 1:
                continue
            # Real-time order: o may only linearize next if it was
            # invoked before the earliest outstanding response.
            if min_res is not None and o.inv > min_res:
                continue
            for state2, result in spec.apply(state, o.op, o.args):
                if not _results_match(result, o.result):
                    continue
                path.append(k)
                if dfs(mask | (1 << k), state2):
                    return True
                path.pop()
        return False

    ok = dfs(0, spec.init())
    return LinResult(ok=ok, linearization=tuple(path) if ok else None,
                     states_explored=explored, ops=ops)


def assert_linearizable(recorder: Recorder, spec: Any,
                        label: str = "") -> LinResult:
    """Check and raise :class:`LinearizabilityViolation` on failure —
    the form scenario ``check`` hooks use."""
    result = check_history(recorder.ops(), spec)
    if not result.ok:
        raise LinearizabilityViolation(
            f"{label or spec.__class__.__name__}: {result.explain()}")
    return result


def ops_from_history(history: Iterable[Tuple]) -> List[OpRecord]:
    """Build OpRecords from raw (task, op, args, result) tuples recorded
    sequentially — each op is a point event (inv immediately followed by
    res).  Convenience for testing specs against known-sequential runs."""
    out = []
    for i, (task, op, args, result) in enumerate(history):
        out.append(OpRecord(op=op, args=tuple(args), result=result,
                            inv=2 * i, res=2 * i + 1, task=task))
    return out
