"""Pure sequential specifications of the lock-free core's semantics.

Each spec is the *abstract* data type a structure claims to implement:
a state machine over hashable states whose ``apply(state, op, args)``
enumerates every ``(next_state, result)`` the sequential type could
produce.  The linearizability checker (:mod:`repro.checker.lin`)
validates recorded concurrent histories against these.

Two deliberate spec-strength decisions, written down here because they
encode *proofs about the implementations*, not checker convenience:

* **SPSC refusals are strict.**  ``HostNBB`` reads its peer counter
  once while its own counter is frozen (it owns it), so the occupancy
  it computes was the true occupancy at the instant of the peer-counter
  load — a FULL/EMPTY refusal really happened at a point inside the
  operation where the ring was full/empty.  The same argument covers
  the MPSC fan-in's EMPTY: only the scanning consumer removes items, so
  if every ring looked empty during the scan, all were simultaneously
  empty at the first probe.  The spec therefore only admits refusals in
  genuinely full/empty abstract states — a refusal under other
  conditions is a real linearizability bug and will be reported.

* **Scan-allocator refusals are weak.**  ``HostBitset.try_claim`` /
  ``RefCountArray.try_claim`` probe slots one CAS at a time while OTHER
  threads claim and release concurrently; a full scan can fail even
  though at every instant some slot was free (the classic weak-scan
  counterexample), so a ``None`` refusal is admitted in any state.
  Successful claims, increfs and releases remain strict.

* **Partial bursts are weak.**  A burst op is not atomic by design: its
  acceptance count ``m`` is decided at the peer-counter load, but the
  items land at the commit/ack store, and the peer can legally change
  occupancy in between (the checker exhibits ``send_burst -> (FULL, 1)``
  with a concurrent drain making space before the commit).  So
  ``(FULL, m)`` with ``0 < m < len(vals)`` and a drain returning fewer
  than ``max_n`` items are admitted whenever the *transfer itself* fits
  the abstract state.  Full acceptance, zero-item FULL refusals and
  empty drains involve a single decisive load and stay strict.
"""
from __future__ import annotations

from typing import Any, Iterable, Tuple

from repro.core import nbb
from repro.checker.lin import MISSING

# ---------------------------------------------------------------------------
# Status normalization: Table-1 codes collapse to their class, because
# transient vs stable (is the peer mid-op?) is timing, not semantics.
# ---------------------------------------------------------------------------
FULL_STATUSES = frozenset({nbb.BUFFER_FULL,
                           nbb.BUFFER_FULL_BUT_CONSUMER_READING})
EMPTY_STATUSES = frozenset({nbb.BUFFER_EMPTY,
                            nbb.BUFFER_EMPTY_BUT_PRODUCER_INSERTING})


def status_class(status: int) -> str:
    if status == nbb.OK:
        return "OK"
    if status in FULL_STATUSES:
        return "FULL"
    if status in EMPTY_STATUSES:
        return "EMPTY"
    raise ValueError(f"unknown status {status}")


class SpscRingSpec:
    """Bounded FIFO — the HostNBB abstract type.

    Ops: ``("send", item) -> "OK" | "FULL"``,
    ``("recv",) -> ("OK", item) | ("EMPTY", None)``,
    ``("send_burst", items) -> (class, n_accepted)``,
    ``("drain", max_n) -> (item, ...)``.
    State: tuple of queued items, oldest first.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity

    def init(self) -> Tuple:
        return ()

    def apply(self, state: Tuple, op: str, args: Tuple
              ) -> Iterable[Tuple[Any, Any]]:
        if op == "send":
            if len(state) >= self.capacity:
                yield state, "FULL"
            else:
                yield state + (args[0],), "OK"
        elif op == "recv":
            if state:
                yield state[1:], ("OK", state[0])
            else:
                yield state, ("EMPTY", None)
        elif op == "send_burst":
            vals = tuple(args[0])
            space = self.capacity - len(state)
            if len(vals) <= space:
                yield state + vals, ("OK", len(vals))   # strict: all fit
            if space == 0 and vals:
                yield state, ("FULL", 0)                # strict: truly full
            # Weak partial acceptance (module docstring): the occupancy
            # snapshot that limited the burst to m < len(vals) items is
            # taken at the peer-counter load, but the insertion lands at
            # the commit, after concurrent drains may have widened space
            # — ("FULL", m) is admitted whenever the m-item prefix fits.
            for m in range(1, len(vals)):
                if m <= space:
                    yield state + vals[:m], ("FULL", m)
        elif op == "drain":
            max_n = args[0]
            avail = len(state) if max_n is None else min(max_n, len(state))
            yield state[avail:], tuple(state[:avail])   # strict full take
            # Weak partial takes: availability is snapshotted at the
            # update-counter load; removal lands at the ack, by which
            # time the producer may have committed more items — any
            # shorter nonempty prefix is admissible.
            for m in range(1, avail):
                yield state[m:], tuple(state[:m])
        else:
            raise ValueError(f"SpscRingSpec: unknown op {op!r}")


class MpscSpec:
    """Fan-in of per-producer FIFOs — the MpscQueue abstract type.

    ``("send", pid, item)`` appends to producer ``pid``'s queue;
    ``("recv",)`` nondeterministically pops the head of ANY nonempty
    queue (the consumer's round-robin order is a fairness policy, not a
    semantic guarantee — only per-producer FIFO is promised).  EMPTY is
    strict (single consumer; see module docstring).
    """

    def __init__(self, nproducers: int, capacity_per_producer: int):
        self.n = nproducers
        self.capacity = capacity_per_producer

    def init(self) -> Tuple:
        return tuple(() for _ in range(self.n))

    def apply(self, state: Tuple, op: str, args: Tuple
              ) -> Iterable[Tuple[Any, Any]]:
        if op == "send":
            pid, item = args
            q = state[pid]
            if len(q) >= self.capacity:
                yield state, "FULL"
            else:
                yield (state[:pid] + (q + (item,),) + state[pid + 1:]), "OK"
        elif op == "recv":
            any_nonempty = False
            for pid in range(self.n):
                q = state[pid]
                if q:
                    any_nonempty = True
                    yield (state[:pid] + (q[1:],) + state[pid + 1:]), \
                        ("OK", q[0])
            if not any_nonempty:
                yield state, ("EMPTY", None)
        else:
            raise ValueError(f"MpscSpec: unknown op {op!r}")


class RefCountSpec:
    """Refcounted slot allocator — the RefCountArray abstract type.

    State: tuple of per-slot counts.  Weak refusals (see module
    docstring): ``try_claim -> None`` and ``claim_specific -> False``
    are admitted in any state (losing the guard to a rival claimer is
    legal obstruction even when the slot stays free).  Counts returned
    by incref/decref are recorded as MISSING by scenarios — the value
    is read after the atomic insert/pop, so it may include neighbors'
    updates; the *count trajectory* is validated by final-state
    invariants instead.
    """

    def __init__(self, nslots: int):
        self.n = nslots

    def init(self) -> Tuple:
        return tuple(0 for _ in range(self.n))

    def _set(self, state: Tuple, i: int, v: int) -> Tuple:
        return state[:i] + (v,) + state[i + 1:]

    def apply(self, state: Tuple, op: str, args: Tuple
              ) -> Iterable[Tuple[Any, Any]]:
        if op == "try_claim":
            for i in range(self.n):
                if state[i] == 0:
                    yield self._set(state, i, 1), i
            yield state, None                     # weak refusal
        elif op == "claim_specific":
            i = args[0]
            if state[i] == 0:
                yield self._set(state, i, 1), True
            yield state, False                    # weak refusal
        elif op == "incref":
            i = args[0]
            if state[i] >= 1:
                yield self._set(state, i, state[i] + 1), MISSING
        elif op == "decref":
            i = args[0]
            if state[i] >= 1:
                yield self._set(state, i, state[i] - 1), MISSING
        else:
            raise ValueError(f"RefCountSpec: unknown op {op!r}")


class BitsetSpec:
    """Binary claim/release allocator — the HostBitset abstract type.
    Same weak-refusal policy as :class:`RefCountSpec`."""

    def __init__(self, nslots: int):
        self.n = nslots

    def init(self) -> Tuple:
        return tuple(False for _ in range(self.n))

    def _set(self, state: Tuple, i: int, v: bool) -> Tuple:
        return state[:i] + (v,) + state[i + 1:]

    def apply(self, state: Tuple, op: str, args: Tuple
              ) -> Iterable[Tuple[Any, Any]]:
        if op == "try_claim":
            for i in range(self.n):
                if not state[i]:
                    yield self._set(state, i, True), i
            yield state, None                     # weak refusal
        elif op == "claim_specific":
            i = args[0]
            if not state[i]:
                yield self._set(state, i, True), True
            yield state, False                    # weak refusal
        elif op == "release":
            i = args[0]
            if state[i]:
                yield self._set(state, i, False), MISSING
        else:
            raise ValueError(f"BitsetSpec: unknown op {op!r}")


class FsmSpec:
    """CAS cell over a transition table — the StateCell abstract type.

    ``("cas", expected, new)``: atomic compare-and-swap semantics — a
    CAS linearized in state ``expected`` MUST succeed, one linearized
    anywhere else MUST fail.  This strictness is what convicts the
    legacy journal-compaction race: a cas that reported a win whose
    transition later evaporated leaves a history no sequential CAS cell
    can produce.  ``("read",)`` returns the current state.
    """

    def __init__(self, table: dict, initial: str):
        self.table = table
        self.initial = initial

    def init(self) -> str:
        return self.initial

    def apply(self, state: str, op: str, args: Tuple
              ) -> Iterable[Tuple[Any, Any]]:
        if op == "cas":
            expected, new = args
            if state == expected and new in self.table[state]:
                yield new, True
            else:
                yield state, False
        elif op == "read":
            yield state, state
        else:
            raise ValueError(f"FsmSpec: unknown op {op!r}")


class PriorityFanSpec:
    """Per-class FIFO fan — the PriorityTransport abstract type at the
    linearizability level: ``("send", cls, item)`` / ``("recv",)`` with
    nondeterministic class choice on recv.  The *priority* policy
    (lowest nonempty class first) is an interval property the scan only
    guarantees against items committed before the scan began, so it is
    validated by scenario invariants over preloaded items, not here.
    """

    def __init__(self, nclasses: int, capacity_per_class: int):
        self._inner = MpscSpec(nclasses, capacity_per_class)

    def init(self) -> Tuple:
        return self._inner.init()

    def apply(self, state, op, args):
        return self._inner.apply(state, op, args)
