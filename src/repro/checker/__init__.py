"""Deterministic concurrency checking for the lock-free core.

Layers (DESIGN.md §15):

* :mod:`repro.core.interleave` — the schedule-controlled
  VirtualScheduler, bounded-DFS explorer, seeded fuzzer and schedule
  minimizer (lives in ``core`` so every primitive can host its yield
  points without an import cycle).
* :mod:`repro.checker.lin` — Wing & Gong linearizability checking over
  recorded histories.
* :mod:`repro.checker.specs` — pure sequential specifications of
  ring/queue/allocator/FSM semantics.
* :mod:`repro.checker.detectors` — torn-read / happens-before detection
  over yield traces (the NBB epoch protocol's Safety property).
* :mod:`repro.checker.scenarios` — the scenario registry: bounded casts
  of tasks + invariants, explored exhaustively in tier-1 and fuzzed at
  larger budgets in ``benchmarks/bench_check.py``.
"""
from repro.checker import detectors, lin, scenarios, specs  # noqa: F401
from repro.checker.lin import (  # noqa: F401
    MISSING, LinearizabilityViolation, OpRecord, Recorder,
    assert_linearizable, check_history,
)
from repro.checker.detectors import (  # noqa: F401
    TornRead, TornReadDetected, assert_no_torn_reads, find_torn_reads,
)
from repro.checker.scenarios import (  # noqa: F401
    SCENARIOS, explore_scenario, fuzz_scenario, replay,
)
