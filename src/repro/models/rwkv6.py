"""RWKV-6 "Finch" block — data-dependent decay linear attention (attn-free).

Time-mix: token-shift interpolation, r/k/v/gate projections, per-channel
data-dependent decay w_t produced by a low-rank MLP (LoRA), WKV recurrence
via the shared chunked-decay primitive (decay applied *after* readout, with
the current-token bonus u), group-norm, silu-gated output projection.

Channel-mix: token-shifted squared-ReLU MLP (d -> d_ff -> d).

Decode carries (shift_tm, shift_cm, wkv_state) per layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamBuilder, rms_norm
from repro.models.linear_attention import (
    decay_linear_attention_chunked, decay_linear_attention_scan)
from repro.parallel.sharding import Axes, shard

RWKV_CLAMP = 5.0  # per-step log-decay clamp; chunk 16 -> 80 nats, f32-safe


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    nh = cfg.d_model // hd
    return nh, hd


def rwkv6_params(make: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    nh, hd = _dims(cfg)
    r = cfg.rwkv.decay_lora
    m = make.scope("rwkv6")
    p = {
        # time-mix
        "mix_r": m("mix_r", (d,), Axes("embed"), scale=0.5),
        "mix_k": m("mix_k", (d,), Axes("embed"), scale=0.5),
        "mix_v": m("mix_v", (d,), Axes("embed"), scale=0.5),
        "mix_g": m("mix_g", (d,), Axes("embed"), scale=0.5),
        "mix_w": m("mix_w", (d,), Axes("embed"), scale=0.5),
        "wr": m("wr", (d, d), Axes("embed", "qkv"), fan_in=d),
        "wk": m("wk", (d, d), Axes("embed", "qkv"), fan_in=d),
        "wv": m("wv", (d, d), Axes("embed", "qkv"), fan_in=d),
        "wg": m("wg", (d, d), Axes("embed", "qkv"), fan_in=d),
        "w0": m("w0", (d,), Axes("qkv"), scale=1.0),
        "w_lora_a": m("w_lora_a", (d, r), Axes("embed", None), fan_in=d),
        "w_lora_b": m("w_lora_b", (r, d), Axes(None, "qkv"), fan_in=r),
        "u_bonus": m("u_bonus", (nh, hd), Axes("heads", "head_dim"), scale=0.3),
        "ln_x": m("ln_x", (d,), Axes("qkv"), scale=1.0),
        "wo": m("wo", (d, d), Axes("qkv", "embed"), fan_in=d),
        # channel-mix
        "cmix_k": m("cmix_k", (d,), Axes("embed"), scale=0.5),
        "cmix_r": m("cmix_r", (d,), Axes("embed"), scale=0.5),
        "ck": m("ck", (d, f), Axes("embed", "mlp"), fan_in=d),
        "cv": m("cv", (f, d), Axes("mlp", "embed"), fan_in=f),
        "cr": m("cr", (d, d), Axes("embed", "qkv"), fan_in=d),
    }
    return p


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=None):
    nh, hd = _dims(cfg)
    dtype = dtype or cfg.compute_dtype
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} (zeros / cache at t=0).  x: [B,T,D]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, cfg: ModelConfig, x: jax.Array,
                   cache: Optional[Dict[str, jax.Array]] = None):
    B, T, D = x.shape
    nh, hd = _dims(cfg)
    xprev = _token_shift(x, cache["shift_tm"] if cache is not None else None)

    def mixed(mix):
        return x + (xprev - x) * mix[None, None, :].astype(x.dtype)

    r = jnp.einsum("btd,de->bte", mixed(p["mix_r"]), p["wr"])
    k = jnp.einsum("btd,de->bte", mixed(p["mix_k"]), p["wk"])
    v = jnp.einsum("btd,de->bte", mixed(p["mix_v"]), p["wv"])
    g = jnp.einsum("btd,de->bte", mixed(p["mix_g"]), p["wg"])
    # Data-dependent decay (LoRA): w_t = exp(-exp(w0 + tanh(x A) B))
    wx = jnp.tanh(jnp.einsum("btd,dr->btr", mixed(p["mix_w"]), p["w_lora_a"]))
    wlog = (p["w0"].astype(jnp.float32)[None, None, :]
            + jnp.einsum("btr,re->bte", wx, p["w_lora_b"]).astype(jnp.float32))
    ld = -jnp.exp(wlog)                                     # [B,T,D] (<0)

    heads = lambda z: z.reshape(B, T, nh, hd)
    initial = cache["wkv"] if cache is not None else None
    chunked = cache is None and T % cfg.rwkv.chunk == 0
    fn = decay_linear_attention_chunked if chunked else decay_linear_attention_scan
    kwargs = dict(chunk=cfg.rwkv.chunk) if chunked else {}
    y, S = fn(heads(r), heads(k), heads(v), heads(ld), u=p["u_bonus"],
              initial_state=initial, decay_at_readout=False,
              clamp=RWKV_CLAMP, **kwargs)
    y = y.reshape(B, T, D)
    y = rms_norm(p["ln_x"], y, cfg.norm_eps)                # stand-in groupnorm
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bte,ed->btd", y, p["wo"])
    new_cache = None
    if cache is not None:
        new_cache = {"shift_tm": x[:, -1], "wkv": S}
    return shard(out, "batch", "seq", "embed"), new_cache


def rwkv6_channel_mix(p, cfg: ModelConfig, x: jax.Array,
                      cache: Optional[Dict[str, jax.Array]] = None):
    xprev = _token_shift(x, cache["shift_cm"] if cache is not None else None)

    def mixed(mix):
        return x + (xprev - x) * mix[None, None, :].astype(x.dtype)

    k = jnp.einsum("btd,df->btf", mixed(p["cmix_k"]), p["ck"])
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "batch", "seq", "mlp")
    kv = jnp.einsum("btf,fd->btd", k, p["cv"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", mixed(p["cmix_r"]), p["cr"]))
    out = r * kv
    new_shift = {"shift_cm": x[:, -1]} if cache is not None else None
    return shard(out, "batch", "seq", "embed"), new_shift
