"""Vocab-safe cross-entropy: never materializes the full [tokens, vocab]
logits tensor (gemma3's 262k vocab at 1M tokens would be ~1 TB).

The hidden states are processed in token chunks via ``lax.scan``; within a
chunk the full-vocab logits exist only transiently (sharded over the model
axis by the "vocab" rule) and are immediately reduced to logsumexp + the
label logit.  This is an online-softmax over the vocab — the same
bounded-slots idea as the paper's ring buffer, applied to the loss.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard


def chunked_softmax_xent(hidden: jax.Array, w_out: jax.Array,
                         labels: jax.Array, token_chunk: int = 2048,
                         weights: Optional[jax.Array] = None,
                         layout: str = "flat") -> jax.Array:
    """hidden: [B, T, D]; w_out: [D, V]; labels: [B, T] int32.

    Returns mean cross-entropy over all weighted tokens (weights default
    to 1; pass 0 to mask, e.g. the final position under rolled labels).

    ``layout`` (ModelConfig.xent_layout) picks the chunk shape — both
    forms were hillclimbed (EXPERIMENTS.md §Perf) and the winner is
    vocab-size/sharding dependent:
      "flat":    [B*T] -> [nchunks, chunk] token chunks.  Best when the
                 vocab is sharded over the model axis (gemma3's 262k,
                 arctic): GSPMD keeps the per-chunk dot local to the
                 vocab shards (batch-preserving form cost +135%
                 collective there).
      "batched": [B, nchunks, chunk] keeps the batch dim first so DP/SP
                 sharding survives the scan.  Best for small vocabs under
                 wide data/sequence parallelism: the flat reshape erases
                 batch sharding and GSPMD re-blocks the scan into a
                 per-256-token sequential loop (measured 4097-trip,
                 2.4 GB/trip on the 256-way smollm cell).
    """
    B, T, D = hidden.shape

    if layout == "batched":
        w = (jnp.ones((B, T), jnp.float32) if weights is None
             else weights.astype(jnp.float32))
        chunk = min(token_chunk, T)
        while T % chunk:
            chunk //= 2
        nchunks = T // chunk
        h = hidden.reshape(B, nchunks, chunk, D).transpose(1, 0, 2, 3)
        y = labels.reshape(B, nchunks, chunk).transpose(1, 0, 2)
        wts = w.reshape(B, nchunks, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def bbody(acc, inp):
            hc, yc, wc = inp                   # [B, chunk, D] etc.
            hc = shard(hc, "batch", None, None)
            logits = jnp.einsum("btd,dv->btv", hc, w_out,
                                preferred_element_type=jnp.float32)
            logits = shard(logits, "batch", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, yc[..., None], axis=2)[..., 0]
            return acc + jnp.sum((lse - ll) * wc), None

        total, _ = jax.lax.scan(bbody, jnp.zeros((), jnp.float32),
                                (h, y, wts))
        return total / jnp.maximum(jnp.sum(wts), 1.0)

    n = B * T
    h = hidden.reshape(n, D)
    y = labels.reshape(n)
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.reshape(n).astype(jnp.float32))
    chunk = min(token_chunk, n)
    while n % chunk:
        chunk //= 2
    nchunks = n // chunk
    h = h.reshape(nchunks, chunk, D)
    y = y.reshape(nchunks, chunk)
    w = w.reshape(nchunks, chunk)

    @jax.checkpoint  # backward recomputes the chunk's logits (never stacked)
    def body(acc, inp):
        hc, yc, wc = inp
        logits = jnp.einsum("td,dv->tv", hc, w_out,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        label_logit = jnp.take_along_axis(logits, yc[:, None], axis=1)[:, 0]
        return acc + jnp.sum((lse - label_logit) * wc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, y, w))
    return total / jnp.maximum(jnp.sum(w), 1.0)


def full_logits(hidden: jax.Array, w_out: jax.Array) -> jax.Array:
    """Decode-path logits (tiny T): [B, T, D] -> [B, T, V]."""
    logits = jnp.einsum("btd,dv->btv", hidden, w_out,
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "vocab")
