"""Chunked linear attention with data-dependent per-channel decay.

One primitive serves both SSM-family archs:

  * Mamba2 / SSD (zamba2): scalar-per-head decay, q=C, k=B, v=dt*x,
  * RWKV-6 "Finch": per-key-channel decay w_t, receptance r as q, bonus u.

Recurrence over state S_t in R^{N x P} (N = key/state channels, P = value):

    S_t = diag(exp(ld_t)) S_{t-1} + k_t v_t^T
    y_t = q_t^T (S applied per `decay_at_readout`)           (mamba: S_t)
    y_t = q_t^T (S_{t-1} + diag(u) k_t v_t^T)                (rwkv)

The chunked form exploits that per-channel decay factors *separate*:
exp(L_t - L_s) = exp(L_t) * exp(-L_s) with L the running log-decay sum, so
the intra-chunk interaction matrix is a plain matmul of decay-scaled q and k
— MXU-friendly, no [C,C,N] blowup.  ``ld`` is clamped at ``-clamp`` per step
so exp(-L_s) stays inside f32 range for a chunk (clamp * chunk <= 80 nats);
contributions below e^-80 are numerically dead anyway.  The sequential-scan
reference (`decay_linear_attention_scan`) applies the same clamp, so chunked
and scan forms agree to float tolerance (property-tested).

This is the TPU-native adaptation of the paper's ring-buffer insight for
recurrent state: the chunk boundary hand-off is the single "message" between
consecutive chunk computations, everything inside a chunk is lock-free
parallel work (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp



def _clamp(ld: jax.Array, clamp: float) -> jax.Array:
    return jnp.clip(ld, -clamp, 0.0)


def decay_linear_attention_scan(
    q: jax.Array, k: jax.Array, v: jax.Array, ld: jax.Array,
    u: Optional[jax.Array] = None,
    initial_state: Optional[jax.Array] = None,
    decay_at_readout: bool = True,
    clamp: float = 5.0,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential reference / decode path.

    q,k: [B,T,H,N]; v: [B,T,H,P]; ld: [B,T,H,N] (log decay, <=0);
    u: [H,N] bonus (rwkv) or None (mamba).
    Returns y [B,T,H,P], final state [B,H,N,P].
    """
    B, T, H, N = q.shape
    P = v.shape[-1]
    ld = _clamp(ld.astype(jnp.float32), clamp)
    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, ldt = inp  # [B,H,N], [B,H,N], [B,H,P], [B,H,N]
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        kv = kt[..., :, None] * vt[..., None, :]            # [B,H,N,P]
        decay = jnp.exp(ldt)[..., :, None]                  # [B,H,N,1]
        if decay_at_readout:
            S_new = decay * S + kv
            y = jnp.einsum("bhn,bhnp->bhp", qt, S_new)
        else:
            read = S + (u[None, :, :, None].astype(jnp.float32) * kv
                        if u is not None else kv)
            y = jnp.einsum("bhn,bhnp->bhp", qt, read)
            S_new = decay * S + kv
        return S_new, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), ld.transpose(1, 0, 2, 3))
    S, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), S


def decay_linear_attention_chunked(
    q: jax.Array, k: jax.Array, v: jax.Array, ld: jax.Array,
    u: Optional[jax.Array] = None,
    initial_state: Optional[jax.Array] = None,
    decay_at_readout: bool = True,
    chunk: int = 64,
    clamp: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel form.  Shapes as in the scan variant; T % chunk == 0."""
    B, T, H, N = q.shape
    P = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    assert clamp * chunk <= 80.0, "decay clamp too loose for f32 exp range"
    C = chunk
    NC = T // C

    ld = _clamp(ld.astype(jnp.float32), clamp)
    f32 = lambda x: x.astype(jnp.float32)

    def reshape_chunks(x):
        return x.reshape(B, NC, C, H, -1).transpose(1, 0, 2, 3, 4)  # [NC,B,C,H,*]

    qc, kc, vc, ldc = map(reshape_chunks, (f32(q), f32(k), f32(v), ld))

    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    causal = jnp.tril(jnp.ones((C, C), jnp.bool_), 0 if decay_at_readout else -1)

    def chunk_step(S, inp):
        qi, ki, vi, ldi = inp                       # [B,C,H,N|P]
        L = jnp.cumsum(ldi, axis=1)                 # inclusive [B,C,H,N]
        Lq = L if decay_at_readout else (L - ldi)   # rwkv reads pre-decay state
        q_in = qi * jnp.exp(Lq)                     # <= |q| (safe)
        k_out = ki * jnp.exp(-L)                    # bounded by clamp*chunk
        # Intra-chunk: separable decay -> plain matmuls.
        A = jnp.einsum("bthn,bshn->bhts", q_in, k_out)
        A = jnp.where(causal[None, None], A, 0.0)
        y = jnp.einsum("bhts,bshp->bthp", A, vi)
        if u is not None:
            # rwkv diagonal bonus: current token with weight u.
            y = y + jnp.einsum("bthn,hn,bthn,bthp->bthp", qi, f32(u), ki, vi)
        # Inter-chunk: read the carried state.
        y = y + jnp.einsum("bthn,bhnp->bthp", q_in, S)
        # State hand-off (the chunk's single "message").
        Ltot = L[:, -1][:, :, :, None]              # [B,H,N,1]
        k_tail = ki * jnp.exp(Ltot.transpose(0, 3, 1, 2) - L)   # [B,C,H,N]
        S_new = jnp.exp(Ltot) * S + jnp.einsum("bthn,bthp->bhnp", k_tail, vi)
        return S_new, y

    S, ys = jax.lax.scan(chunk_step, S0, (qc, kc, vc, ldc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, P)
    return y.astype(v.dtype), S
