"""Shared transformer layers: norms, RoPE, GQA attention, SwiGLU MLP.

All layers are pure functions over explicit param dicts.  Parameters are
created through a :class:`ParamBuilder` callback so the same builder code
yields (a) randomly-initialized arrays, (b) logical-axes metadata for
sharding, or (c) abstract shapes for the dry-run — one source of truth.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import Axes, shard


# ---------------------------------------------------------------------------
# Parameter builder
# ---------------------------------------------------------------------------
class ParamBuilder:
    """make(name, shape, axes, fan_in=None) -> array | Axes.

    mode="init": fan-in scaled normal init, keyed by a stable hash of the
    parameter path so layer stacking via vmap stays reproducible.
    mode="axes": returns the Axes metadata leaf (for sharding specs).
    """

    def __init__(self, mode: str, rng: Optional[jax.Array] = None,
                 dtype=jnp.bfloat16, prefix: str = ""):
        assert mode in ("init", "axes")
        self.mode = mode
        self.rng = rng
        self.dtype = dtype
        self.prefix = prefix

    def scope(self, name: str) -> "ParamBuilder":
        return ParamBuilder(self.mode, self.rng, self.dtype,
                            self.prefix + name + "/")

    def __call__(self, name: str, shape: Tuple[int, ...], axes: Axes,
                 fan_in: Optional[int] = None, zero: bool = False,
                 scale: Optional[float] = None):
        assert len(shape) == len(axes.names), (self.prefix + name, shape, axes)
        if self.mode == "axes":
            return axes
        path = self.prefix + name
        if zero:
            return jnp.zeros(shape, self.dtype)
        key = jax.random.fold_in(self.rng, zlib.crc32(path.encode()))
        if scale is None:
            fi = fan_in if fan_in is not None else (shape[0] if shape else 1)
            scale = fi ** -0.5
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(self.dtype)


def ones_param(make: ParamBuilder, name: str, dim: int) -> Any:
    if make.mode == "axes":
        return Axes("embed")
    return jnp.ones((dim,), make.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None].astype(jnp.float32) * freq  # [..., seq, half]
    cos = jnp.cos(angle)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angle)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA self / cross, optional sliding window, optional KV cache)
# ---------------------------------------------------------------------------
def attention_params(make: ParamBuilder, cfg: ModelConfig,
                     cross: bool = False) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    m = make.scope("cross_attn" if cross else "attn")
    p = {
        "wq": m("wq", (d, nh, hd), Axes("embed", "heads", "head_dim"), fan_in=d),
        "wk": m("wk", (d, nkv, hd), Axes("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": m("wv", (d, nkv, hd), Axes("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": m("wo", (nh, hd, d), Axes("heads", "head_dim", "embed"), fan_in=nh * hd),
    }
    if cfg.qk_norm:
        if make.mode == "init":
            p["q_norm"] = jnp.ones((hd,), make.dtype)
            p["k_norm"] = jnp.ones((hd,), make.dtype)
        else:
            p["q_norm"] = Axes("head_dim")
            p["k_norm"] = Axes("head_dim")
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0,
                  dtype=None):
    """Ring-buffer KV cache for one layer.  A sliding-window cache *is* a
    circular buffer indexed by position mod window — the NBB slot-rotation
    idea applied to attention state (DESIGN.md §2)."""
    size = min(window, max_len) if window else max_len
    dtype = dtype or cfg.compute_dtype
    kv = (batch, size, cfg.num_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}


def _shard_cache(c):
    return {
        "k": shard(c["k"], "batch", "cache_seq", "cache_kv_heads", "head_dim"),
        "v": shard(c["v"], "batch", "cache_seq", "cache_kv_heads", "head_dim"),
    }


def is_paged(cache) -> bool:
    """A *paged* cache view (DESIGN.md §10): the KV pool's page arrays
    plus a per-row block table, instead of a dense per-slot ring.  The
    leaves ride the same pytree plumbing as a dense cache, so
    ``decode_loop``/``chunked_block`` run unmodified over either
    backend."""
    return isinstance(cache, dict) and "pages_k" in cache


def attention(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
              positions: jax.Array,
              window: int = 0,
              cache: Optional[Dict[str, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_source: Optional[jax.Array] = None,
              write_mask: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention.

    x: [B, T, D]; positions: [B, T] absolute positions of x tokens.
    window: sliding-window size (0 = global causal).
    cache/cache_pos: decode-mode ring cache and the write position —
        a scalar (whole batch in lockstep, wave scheduling) or a [B]
        vector (per-row positions, the slot-swap continuous batcher:
        each decode slot advances independently, DESIGN.md §4).
    kv_source: cross-attention source [B, S, D] (no causal mask, no rope).
    write_mask: [B, T] bool, per-token cache-write validity (chunked
        zero-copy admission, DESIGN.md §9): positions where the mask is
        False keep the cache's old value, so a fixed-shape prompt chunk
        can be written in place into only the admitting rows of the
        batch cache.  Requires a [B]-vector cache_pos.

    Returns (out [B,T,D], updated cache or None).
    """
    B, T, D = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    cross = kv_source is not None

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    kv_in = kv_source if cross else x
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"])

    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)

    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q = shard(q, "batch", "seq", "heads", "head_dim")

    new_cache = None
    if cache is not None and is_paged(cache):
        # Paged KV residency (DESIGN.md §10): the pool's page arrays ARE
        # the cache; this row's history is addressed through its block
        # table.  Writes scatter the T new tokens to (page, offset)
        # computed on device; reads gather the pages back into position
        # order so the shared ``attend`` below sees exactly the dense
        # layout — token sequences stay byte-identical to the dense
        # backend.  Pages are position-ordered, so a slot's kv position
        # IS its gather index: no ring arithmetic, no wrap epoch.
        kp, vp = cache["pages_k"], cache["pages_v"]
        li, blockt = cache["layer"], cache["block"]
        n_pages, ps = kp.shape[0], kp.shape[1]
        cp = jnp.asarray(cache_pos)
        if cp.ndim == 0:
            raise ValueError("paged cache requires a [B] vector cache_pos "
                             "(per-row block tables)")
        idx = cp[:, None] + jnp.arange(T)                   # [B, T] positions
        page = jnp.take_along_axis(blockt, idx // ps, axis=1, mode="clip")
        off = idx % ps
        k_new = k.astype(kp.dtype)
        v_new = v.astype(vp.dtype)
        if write_mask is not None:
            # Rows not writing this dispatch (idle/dead decode rows, the
            # padded tail of a final chunk) must DROP their writes: in
            # the shared pool a masked row's junk write could land in a
            # page another sequence owns — unlike the dense cache, where
            # each row's junk stays in its own private rows.  An
            # out-of-range page index + scatter mode="drop" is the
            # write-enable.
            page = jnp.where(write_mask, page, n_pages)
            total = cp[:, None] + jnp.sum(write_mask, axis=1,
                                          keepdims=True)   # [B, 1]
        else:
            total = cp[:, None] + T
        kp = kp.at[page, off, li].set(k_new, mode="drop")
        vp = vp.at[page, off, li].set(v_new, mode="drop")
        new_cache = {"pages_k": kp, "pages_v": vp, "block": blockt,
                     "layer": li}
        S = blockt.shape[1] * ps
        # Gather ONLY this batch's pages at this layer ([B, P, ps, kv,
        # hd] — O(B*max_len), not O(n_pages)): the jnp expression of the
        # Pallas kernel's per-page index_map DMA.
        k = kp[blockt, :, li].reshape(B, S, nkv, hd)
        v = vp[blockt, :, li].reshape(B, S, nkv, hd)
        kv_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        kv_valid = jnp.arange(S)[None, :] < total           # [B, S]
    elif cache is not None:
        size = cache["k"].shape[1]
        cp = jnp.asarray(cache_pos)
        if cp.ndim == 0:
            if write_mask is not None:
                raise ValueError("write_mask requires a [B] vector "
                                 "cache_pos (per-row chunked admission)")
            # Lockstep decode: write k/v of the T new tokens into the
            # same ring slots for every batch row.
            slots = (cp + jnp.arange(T)) % size             # [T]
            k_full = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
            v_full = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
            total = cp + T                                  # tokens so far
            slot_ids = jnp.arange(size)
            valid = slot_ids < jnp.minimum(total, size)     # [S]
            # Absolute position held by each slot (causal/window masking).
            wraps = (total - 1) // size
            slot_pos = jnp.where(
                slot_ids <= (total - 1) % size,
                wraps * size + slot_ids,
                jnp.maximum(wraps - 1, 0) * size + slot_ids,
            )                                               # [S]
            kv_pos = jnp.broadcast_to(slot_pos, (B, size))
            kv_valid = jnp.broadcast_to(valid, (B, size))
        else:
            # Per-row decode (slot-swap continuous batching): every batch
            # row is an independent sequence at its own position; rows
            # whose slot is idle write to slot 0 but are masked out by
            # their own row's validity, never by neighbours'.
            slots = (cp[:, None] + jnp.arange(T)) % size    # [B, T]
            b_idx = jnp.arange(B)[:, None]
            k_new = k.astype(cache["k"].dtype)
            v_new = v.astype(cache["v"].dtype)
            if write_mask is not None:
                # Chunked admission: only slots actually carrying prompt
                # tokens are written; every other (row, slot) keeps its
                # old value via a gather+where on the T touched slots —
                # the full cache is never copied.
                wm = write_mask[:, :, None, None]
                k_new = jnp.where(wm, k_new, cache["k"][b_idx, slots])
                v_new = jnp.where(wm, v_new, cache["v"][b_idx, slots])
            k_full = cache["k"].at[b_idx, slots].set(k_new)
            v_full = cache["v"].at[b_idx, slots].set(v_new)
            if write_mask is not None:
                # The row's true extent is its VALID token count, not T:
                # counting a final chunk's padded tail would (a) mark
                # never-written slots valid and (b) push ``total`` past
                # the ring size, bumping the wrap epoch and mislabeling
                # the oldest slots' positions — causally masking real
                # prompt KV from the chunk's own queries.
                total = cp[:, None] + jnp.sum(write_mask, axis=1,
                                              keepdims=True)  # [B, 1]
            else:
                total = cp[:, None] + T                     # [B, 1]
            slot_ids = jnp.arange(size)[None, :]            # [1, S]
            valid = slot_ids < jnp.minimum(total, size)     # [B, S]
            wraps = (total - 1) // size
            slot_pos = jnp.where(
                slot_ids <= (total - 1) % size,
                wraps * size + slot_ids,
                jnp.maximum(wraps - 1, 0) * size + slot_ids,
            )                                               # [B, S]
            kv_pos, kv_valid = slot_pos, valid
        new_cache = _shard_cache({"k": k_full, "v": v_full})
        k, v = new_cache["k"], new_cache["v"]
    else:
        kv_pos = positions if not cross else None
        kv_valid = None

    k = shard(k, "batch", "cache_seq" if cache is not None else "seq",
              "kv_heads" if cache is None else "cache_kv_heads", "head_dim")
    v = shard(v, "batch", "cache_seq" if cache is not None else "seq",
              "kv_heads" if cache is None else "cache_kv_heads", "head_dim")

    # GQA: fold the group dimension into q.
    group = nh // nkv
    qg = q.reshape(B, T, nkv, group, hd)

    softcap = cfg.attn_logit_softcap

    def attend(q_blk, q_pos_blk):
        """q_blk: [B, t, kv, g, hd]; q_pos_blk: [B, t].  Full-S attention of
        one query block (memory O(t*S), bounded by the chunk loop below)."""
        scores = jnp.einsum("btkgh,bskh->bkgts", q_blk, k,
                            preferred_element_type=jnp.float32) * (hd ** -0.5)
        if softcap:
            scores = jnp.tanh(scores / softcap) * softcap
        if not cross:
            mask = kv_pos[:, None, :] <= q_pos_blk[:, :, None]   # causal
            if window:
                mask &= kv_pos[:, None, :] > q_pos_blk[:, :, None] - window
            if kv_valid is not None:
                mask &= kv_valid[:, None, :]
            scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgts,bskh->btkgh", probs, v)

    # Query-chunked attention for LONG sequences only.  Measured on the
    # dry-run (EXPERIMENTS.md §Perf, "full-length loss" iteration): at
    # T=4k the chunk scan *adds* fusion-boundary HBM traffic (+24-28%
    # memory term) versus one fused attend, while at 32k the unchunked
    # [T,S] f32 scores (4 GB/head) are unshippable — so chunk iff T >= 8k.
    qchunk = 2048
    if T >= 8192 and T % qchunk == 0:
        # Scan over query blocks so the [t, S] score tile is the only
        # transient (the Pallas flash kernel mirrors this blocking
        # on-chip).  The chunk body is rematted: backward recomputes
        # each tile instead of saving T/qchunk of them.
        nq = T // qchunk
        q_blks = qg.reshape(B, nq, qchunk, nkv, group, hd).transpose(
            1, 0, 2, 3, 4, 5)
        p_blks = positions.reshape(B, nq, qchunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(_, inp):
            qb, pb = inp
            return None, attend(qb, pb)

        _, out = jax.lax.scan(body, None, (q_blks, p_blks))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, nh, hd)
    else:
        out = attend(qg, positions).reshape(B, T, nh, hd)

    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return shard(out, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_params(make: ParamBuilder, cfg: ModelConfig,
               d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    m = make.scope("mlp")
    return {
        "wi_gate": m("wi_gate", (d, f), Axes("embed", "mlp"), fan_in=d),
        "wi_up": m("wi_up", (d, f), Axes("embed", "mlp"), fan_in=d),
        "wo": m("wo", (f, d), Axes("mlp", "embed"), fan_in=f),
    }


def mlp(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["wi_gate"]))
    h = h * jnp.einsum("btd,df->btf", x, p["wi_up"])
    h = shard(h, "batch", "seq", "mlp")
    out = jnp.einsum("btf,fd->btd", h, p["wo"])
    return shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_params(make: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    m = make.scope("embed")
    p = {"table": m("table", (cfg.vocab_size, cfg.d_model),
                    Axes("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = m("unembed", (cfg.d_model, cfg.vocab_size),
                         Axes("embed", "vocab"), fan_in=cfg.d_model)
    return p


def embed(p: Dict[str, Any], tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def unembed_matrix(p: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    """Returns W_out [d_model, vocab]."""
    if cfg.tie_embeddings:
        return p["table"].T
    return p["unembed"]
