"""Mixture-of-Experts layer with expert parallelism (arctic, olmoe).

Token-choice top-k routing with per-expert capacity.  Experts are sharded
over the ``model`` mesh axis; expert weights are additionally FSDP-sharded
over ``data`` and all-gathered just-in-time (ZeRO-3 style) so arctic-480B's
468B expert parameters fit 16 GB/chip.

The distributed form runs under ``shard_map`` so all dispatch index math is
*local* (no GSPMD scatter surprises, no fake one-hot dispatch FLOPs):
activations are replicated across the model axis (they already are at this
point of a Megatron-style block), every model column routes the same tokens,
keeps only the choices that land on its own experts, computes them densely
at capacity, and the combine is the block's usual output ``psum``.

Communication pattern: each expert column consumes exactly the token slots
addressed to it and produces partial outputs merged by one reduction — the
MCAPI "client endpoints -> server receive queue" fan-in of the paper's
Figure 1, with slot-disjoint writes instead of a global lock (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ParamBuilder
from repro.parallel import sharding
from repro.parallel.sharding import Axes


def moe_params(make: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    mo = cfg.moe
    d, f, E = cfg.d_model, mo.d_ff_expert, mo.num_experts
    m = make.scope("moe")
    p = {
        "router": m("router", (d, E), Axes("embed", None), fan_in=d),
        "wi_gate": m("wi_gate", (E, d, f),
                     Axes("expert", "expert_data", "expert_mlp"), fan_in=d),
        "wi_up": m("wi_up", (E, d, f),
                   Axes("expert", "expert_data", "expert_mlp"), fan_in=d),
        "wo": m("wo", (E, f, d),
                Axes("expert", "expert_mlp", "expert_data"), fan_in=f),
    }
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    mo = cfg.moe
    c = int(num_tokens * mo.top_k * mo.capacity_factor / mo.num_experts)
    return max(c, 1)


def _route(cfg: ModelConfig, x: jax.Array, router_w: jax.Array):
    """x: [t, d] -> (gates [t,k] f32, eids [t,k] i32, aux_loss scalar)."""
    mo = cfg.moe
    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, mo.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    density = jnp.mean(probs, axis=0)                               # [E]
    frac = jnp.mean(
        jax.nn.one_hot(eids[:, 0], mo.num_experts, dtype=jnp.float32), axis=0)
    aux = mo.num_experts * jnp.sum(density * frac)
    return gates, eids, aux


def _expert_compute(cfg: ModelConfig, x: jax.Array, gates, eids,
                    w_gate, w_up, w_down, base: jax.Array, e_local: int):
    """Dense-at-capacity compute for the ``e_local`` experts starting at
    ``base``.  All index math local.  x: [t, d]."""
    t, d = x.shape
    k = cfg.moe.top_k
    C = _capacity(t, cfg)

    eids_f = eids.reshape(-1)                       # [t*k]
    gates_f = gates.reshape(-1)
    local = (eids_f >= base) & (eids_f < base + e_local)
    el = jnp.where(local, eids_f - base, e_local)   # overflow bucket e_local
    # Position of each choice within its expert's capacity (FIFO by token id —
    # each expert's slot sequence is an order-preserving queue).
    onehot = jax.nn.one_hot(el, e_local + 1, dtype=jnp.int32)     # [t*k, el+1]
    pos = jnp.cumsum(onehot, axis=0) - onehot                      # exclusive
    pos_sel = jnp.take_along_axis(pos, el[:, None], axis=1)[:, 0]  # [t*k]
    keep = local & (pos_sel < C)
    slot = jnp.where(keep, el * C + pos_sel, e_local * C)          # sentinel

    # Dispatch: scatter token ids into slots, gather activations.
    token_ids = jnp.arange(t * k, dtype=jnp.int32) // k
    slot_token = jnp.full((e_local * C + 1,), t, jnp.int32)        # t = pad row
    slot_token = slot_token.at[slot].set(jnp.where(keep, token_ids, t))
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[slot_token[:-1]].reshape(e_local, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)                     # [el, C, d]

    # Combine: gather each kept choice's output, weight by its gate.
    ye_flat = jnp.concatenate(
        [ye.reshape(e_local * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    y_choice = ye_flat[slot]                                       # [t*k, d]
    y_choice = jnp.where(keep[:, None], y_choice, 0)
    out = jnp.sum(
        (y_choice * gates_f[:, None].astype(y_choice.dtype)).reshape(t, k, d),
        axis=1)
    return out


def _moe_local(cfg: ModelConfig, x: jax.Array, p: Dict[str, Any]):
    """Single-device path (smoke tests, no mesh)."""
    B, T, D = x.shape
    xf = x.reshape(B * T, D)
    gates, eids, aux = _route(cfg, xf, p["router"])
    out = _expert_compute(cfg, xf, gates, eids, p["wi_gate"], p["wi_up"],
                          p["wo"], jnp.int32(0), cfg.moe.num_experts)
    return out.reshape(B, T, D), aux


def moe_block(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, D] -> (out [B, T, D], aux_loss scalar)."""
    if not sharding.active():
        return _moe_local(cfg, x, p)

    mesh = sharding._ctx.mesh
    axes = set(mesh.axis_names)
    model_ax = "model"
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    B = x.shape[0]
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    batch_spec = batch_axes if (batch_axes and B % bsz == 0) else None
    data_ax = "data" if "data" in axes else None
    e_local = cfg.moe.num_experts // mesh.shape[model_ax]

    def local_fn(x_loc, router_w, w_gate, w_up, w_down):
        Bl, Tl, Dl = x_loc.shape
        xf = x_loc.reshape(Bl * Tl, Dl)
        gates, eids, aux = _route(cfg, xf, router_w)
        if data_ax is not None:
            # ZeRO-3: gather the FSDP-sharded expert weights just in time.
            w_gate = jax.lax.all_gather(w_gate, data_ax, axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, data_ax, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, data_ax, axis=2, tiled=True)
        base = jax.lax.axis_index(model_ax) * e_local
        out = _expert_compute(cfg, xf, gates, eids, w_gate, w_up, w_down,
                              base, e_local)
        out = jax.lax.psum(out, model_ax)
        aux = jax.lax.pmean(aux, batch_axes) if batch_spec else aux
        return out.reshape(Bl, Tl, Dl), aux

    wspec_in = P(model_ax, data_ax, None)
    wspec_out = P(model_ax, None, data_ax)
    out, aux = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(batch_spec, None, None), P(None, None),
                  wspec_in, wspec_in, wspec_out),
        out_specs=(P(batch_spec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    return out, aux
