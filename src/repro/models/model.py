"""Model assembly: build any assigned architecture from its ModelConfig.

``build_model(cfg)`` returns a :class:`Model` exposing:

  init(rng) -> params                  param_axes() -> Axes tree
  loss(params, batch) -> (scalar, metrics)          [train step body]
  prefill(params, batch, max_len) -> (cache, last_tok)
  decode_step(params, cache, tokens, pos) -> (next_tok, cache)
  decode_loop(params, cache, cur, pos, rem, eos, k=, max_len=)
      -> (token block [B, k], cache)        [fused packet-mode decode]
  prefill_chunk_into(params, cache, chunk, start, n_valid)
      -> (next_tok [B], cache)     [chunked zero-copy in-place admission]
  chunked_block(...same..., cur, pos, rem, eos, k=, max_len=)
      -> (next_tok, block, cache)  [one dispatch: chunk + K decode steps]
  init_cache(batch, max_len) -> abstract cache (zeros)

Layer stacks are scanned (stacked params) so HLO size is O(1) in depth;
heterogeneous archs (gemma3 local:global, zamba2 shared-attn hybrid, vlm
cross-attn) scan over *superblocks*.  Every train-mode block is wrapped in
``jax.checkpoint`` with a configurable remat policy.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mamba2 as m2
from repro.models import rwkv6 as rw
from repro.models.config import ModelConfig
from repro.models.layers import (ParamBuilder, attention, attention_params,
                                 embed, embed_params, init_kv_cache,
                                 is_paged, mlp, mlp_params, rms_norm,
                                 unembed_matrix)
from repro.models.losses import chunked_softmax_xent, full_logits
from repro.models.moe import moe_block, moe_params
from repro.parallel.sharding import Axes, shard

_REMAT_POLICIES = {
    "nothing": None,  # jax.checkpoint default: save nothing inside the block
    "dots": "dots_with_no_batch_dims_saveable",
}


def _is_axes(x):
    return isinstance(x, Axes)


def stack_params(n: int, build_fn: Callable[[ParamBuilder], Any],
                 make: ParamBuilder, name: str):
    """Stack ``n`` independently-initialized copies of a param subtree."""
    scoped = make.scope(name)
    if make.mode == "axes":
        tree = build_fn(scoped)
        return jax.tree.map(lambda a: a.prepend("layers"), tree,
                            is_leaf=_is_axes)
    keys = jax.random.split(scoped.rng, n)
    return jax.vmap(
        lambda k: build_fn(ParamBuilder("init", k, scoped.dtype, scoped.prefix))
    )(keys)


# ---------------------------------------------------------------------------
# Blocks: pre-norm residual units.
# ---------------------------------------------------------------------------
def _norm_param(make: ParamBuilder, name: str, dim: int):
    if make.mode == "axes":
        return Axes("embed")
    return jnp.ones((dim,), make.dtype)


def attn_block_params(make: ParamBuilder, cfg: ModelConfig,
                      with_mlp: bool = True, cross: bool = False,
                      d_ff: Optional[int] = None):
    p = {
        "ln1": _norm_param(make, "ln1", cfg.d_model),
        "attn": attention_params(make, cfg, cross=cross),
    }
    if with_mlp:
        p["ln2"] = _norm_param(make, "ln2", cfg.d_model)
        p["mlp"] = mlp_params(make, cfg, d_ff=d_ff)
    return p


def attn_block(p, cfg: ModelConfig, x, positions, window=0, cache=None,
               cache_pos=None, kv_source=None, causal=True,
               static_cache=False, write_mask=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    if static_cache:
        # Cross-attention against precomputed (cached) K/V.
        a, new_cache = _attend_static(p["attn"], cfg, h, cache), cache
    else:
        a, new_cache = attention(p["attn"], cfg, h, positions, window=window,
                                 cache=cache, cache_pos=cache_pos,
                                 kv_source=kv_source, write_mask=write_mask)
    x = x + a
    if "mlp" in p:
        x = x + mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


def _attend_static(pa, cfg: ModelConfig, x, kv_cache):
    """Decode-time cross-attention: q against precomputed k/v (no mask)."""
    B, T, D = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = jnp.einsum("btd,dhk->bthk", x, pa["wq"])
    if cfg.qk_norm:
        q = rms_norm(pa["q_norm"], q, cfg.norm_eps)
    k, v = kv_cache["k"], kv_cache["v"]
    group = nh // nkv
    qg = q.reshape(B, T, nkv, group, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v).reshape(B, T, nh, hd)
    return jnp.einsum("bthk,hkd->btd", out, pa["wo"])


def cross_kv(pa, cfg: ModelConfig, source: jax.Array):
    """Precompute cross-attention K/V from an encoder/image source."""
    k = jnp.einsum("bsd,dhk->bshk", source, pa["wk"])
    v = jnp.einsum("bsd,dhk->bshk", source, pa["wv"])
    if cfg.qk_norm:
        k = rms_norm(pa["k_norm"], k, cfg.norm_eps)
    return {"k": k, "v": v}


def moe_block_params(make: ParamBuilder, cfg: ModelConfig):
    p = {
        "ln1": _norm_param(make, "ln1", cfg.d_model),
        "attn": attention_params(make, cfg),
        "ln2": _norm_param(make, "ln2", cfg.d_model),
        "moe": moe_params(make, cfg),
    }
    if cfg.moe.dense_residual:
        p["dense"] = mlp_params(make, cfg)
    return p


def moe_layer(p, cfg: ModelConfig, x, positions, cache=None, cache_pos=None,
              write_mask=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = attention(p["attn"], cfg, h, positions, cache=cache,
                             cache_pos=cache_pos, write_mask=write_mask)
    x = x + a
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    y, aux = moe_block(p["moe"], cfg, h)
    if "dense" in p:
        y = y + mlp(p["dense"], h)  # arctic: dense residual in parallel
    return x + y, new_cache, aux


def mamba_layer_params(make: ParamBuilder, cfg: ModelConfig):
    return {"ln": _norm_param(make, "ln", cfg.d_model),
            "mixer": m2.mamba2_params(make, cfg)}


def mamba_layer(p, cfg: ModelConfig, x, cache=None):
    h = rms_norm(p["ln"], x, cfg.norm_eps)
    y, new_cache = m2.mamba2_block(p["mixer"], cfg, h, cache=cache)
    return x + y, new_cache


def rwkv_layer_params(make: ParamBuilder, cfg: ModelConfig):
    return {"ln1": _norm_param(make, "ln1", cfg.d_model),
            "ln2": _norm_param(make, "ln2", cfg.d_model),
            "rwkv": rw.rwkv6_params(make, cfg)}


def rwkv_layer(p, cfg: ModelConfig, x, cache=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    y, c1 = rw.rwkv6_time_mix(p["rwkv"], cfg, h, cache=cache)
    x = x + y
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    y, c2 = rw.rwkv6_channel_mix(p["rwkv"], cfg, h, cache=cache)
    x = x + y
    new_cache = None
    if cache is not None:
        new_cache = {**c1, **c2}
    return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    remat_policy: str = "nothing"

    # -- parameters ----------------------------------------------------------
    def _build(self, make: ParamBuilder):
        cfg = self.cfg
        p: Dict[str, Any] = {"embed": embed_params(make.scope("tok"), cfg)}
        fam = self._structure()
        if fam == "uniform_attn":
            p["layers"] = stack_params(cfg.num_layers,
                                       lambda m: attn_block_params(m, cfg),
                                       make, "layers")
        elif fam == "uniform_moe":
            p["layers"] = stack_params(cfg.num_layers,
                                       lambda m: moe_block_params(m, cfg),
                                       make, "layers")
        elif fam == "uniform_rwkv":
            p["layers"] = stack_params(cfg.num_layers,
                                       lambda m: rwkv_layer_params(m, cfg),
                                       make, "layers")
        elif fam == "gemma_local_global":
            per, nsb, tail = self._gemma_plan()
            p["super"] = stack_params(
                nsb,
                lambda m: {
                    "local": stack_params(per, lambda mm: attn_block_params(mm, cfg),
                                          m, "local"),
                    "global": attn_block_params(m.scope("global"), cfg),
                }, make, "super")
            if tail:
                p["tail"] = stack_params(tail,
                                         lambda m: attn_block_params(m, cfg),
                                         make, "tail")
        elif fam == "zamba_hybrid":
            nsb, per = self._zamba_plan()
            p["shared_attn"] = attn_block_params(
                make.scope("shared_attn"), cfg, with_mlp=True, d_ff=cfg.d_ff)
            p["super"] = stack_params(
                nsb,
                lambda m: stack_params(per, lambda mm: mamba_layer_params(mm, cfg),
                                       m, "mamba"),
                make, "super")
        elif fam == "vlm_cross":
            nsb, per, cross_at = self._vlm_plan()
            p["super"] = stack_params(
                nsb,
                lambda m: {
                    "selfs": stack_params(per - 1,
                                          lambda mm: attn_block_params(mm, cfg),
                                          m, "selfs"),
                    "cross": attn_block_params(m.scope("cross"), cfg, cross=True),
                }, make, "super")
        elif fam == "enc_dec":
            enc = self.cfg.encoder
            p["encoder"] = stack_params(enc.num_layers,
                                        lambda m: attn_block_params(m, cfg),
                                        make, "encoder")
            p["enc_norm"] = _norm_param(make, "enc_norm", cfg.d_model)
            p["layers"] = stack_params(
                cfg.num_layers,
                lambda m: {
                    "self": attn_block_params(m, cfg, with_mlp=False),
                    "cross": attn_block_params(m, cfg, with_mlp=True, cross=True),
                }, make, "decoder")
        else:
            raise ValueError(fam)
        p["final_norm"] = _norm_param(make, "final_norm", cfg.d_model)
        return p

    def _structure(self) -> str:
        cfg = self.cfg
        if cfg.encoder is not None:
            return "enc_dec"
        if cfg.rwkv is not None:
            return "uniform_rwkv"
        if cfg.ssm is not None:
            return "zamba_hybrid"
        if cfg.cross_attn_every:
            return "vlm_cross"
        if cfg.moe is not None:
            return "uniform_moe"
        if cfg.local_global_ratio:
            return "gemma_local_global"
        return "uniform_attn"

    def _gemma_plan(self):
        per = self.cfg.local_global_ratio          # local layers per global
        block = per + 1
        nsb = self.cfg.num_layers // block
        tail = self.cfg.num_layers - nsb * block   # trailing local layers
        return per, nsb, tail

    def _zamba_plan(self):
        per = self.cfg.attn_every                  # mamba layers per superblock
        nsb = self.cfg.num_layers // per
        assert nsb * per == self.cfg.num_layers
        return nsb, per

    def _vlm_plan(self):
        per = self.cfg.cross_attn_every
        nsb = self.cfg.num_layers // per
        assert nsb * per == self.cfg.num_layers
        return nsb, per, per - 2  # cross sits at index per-2 (e.g. 3 of 0..4)

    def init(self, rng: jax.Array):
        make = ParamBuilder("init", rng, self.cfg.compute_dtype)
        return self._build(make)

    def param_axes(self):
        return self._build(ParamBuilder("axes"))

    # -- forward -------------------------------------------------------------
    def _remat(self, fn):
        pol = self.remat_policy
        if pol == "none":
            return fn
        if pol == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    def _run_stack(self, params, x, positions, caches, cache_pos, train,
                   extras=None, write_mask=None):
        """Returns (hidden, new_caches, aux_loss)."""
        cfg = self.cfg
        fam = self._structure()
        aux = jnp.zeros((), jnp.float32)
        decode = caches is not None

        # Paged KV residency (DESIGN.md §10): the pool page arrays ride
        # the scan CARRY (every layer scatters into its own [:, :, li]
        # plane of the same donated buffers) and the layer index rides
        # the xs — the block table is read-only on device and closed
        # over.  Only the uniform families are pageable (one KV shape,
        # position-indexed, no sliding-window ring).
        paged = decode and is_paged(caches)

        if fam == "uniform_attn":
            if paged:
                block = caches["block"]

                def pbody(carry, inp):
                    x, kp, vp = carry
                    lp, li = inp
                    out, nc = attn_block(
                        lp, cfg, x, positions,
                        cache={"pages_k": kp, "pages_v": vp,
                               "block": block, "layer": li},
                        cache_pos=cache_pos, write_mask=write_mask)
                    return (out, nc["pages_k"], nc["pages_v"]), None
                (x, kp, vp), _ = jax.lax.scan(
                    pbody, (x, caches["pages_k"], caches["pages_v"]),
                    (params["layers"], jnp.arange(cfg.num_layers)))
                return x, {"pages_k": kp, "pages_v": vp, "block": block}, aux

            def body(x, inp):
                lp, c = inp
                out, nc = attn_block(lp, cfg, x, positions,
                                     window=cfg.sliding_window,
                                     cache=c, cache_pos=cache_pos,
                                     write_mask=write_mask)
                return out, nc
            f = body if decode else self._remat(body)
            x, new_caches = jax.lax.scan(f, x, (params["layers"], caches))

        elif fam == "uniform_moe":
            if paged:
                block = caches["block"]

                def pbody(carry, inp):
                    x, aux, kp, vp = carry
                    lp, li = inp
                    out, nc, a = moe_layer(
                        lp, cfg, x, positions,
                        cache={"pages_k": kp, "pages_v": vp,
                               "block": block, "layer": li},
                        cache_pos=cache_pos, write_mask=write_mask)
                    return (out, aux + a, nc["pages_k"], nc["pages_v"]), None
                (x, aux, kp, vp), _ = jax.lax.scan(
                    pbody, (x, aux, caches["pages_k"], caches["pages_v"]),
                    (params["layers"], jnp.arange(cfg.num_layers)))
                return x, {"pages_k": kp, "pages_v": vp, "block": block}, aux

            def body(carry, inp):
                x, aux = carry
                lp, c = inp
                out, nc, a = moe_layer(lp, cfg, x, positions, cache=c,
                                       cache_pos=cache_pos,
                                       write_mask=write_mask)
                return (out, aux + a), nc
            f = body if decode else self._remat(body)
            (x, aux), new_caches = jax.lax.scan(
                f, (x, aux), (params["layers"], caches))

        elif fam == "uniform_rwkv":
            def body(x, inp):
                lp, c = inp
                return rwkv_layer(lp, cfg, x, cache=c)
            f = body if decode else self._remat(body)
            x, new_caches = jax.lax.scan(f, x, (params["layers"], caches))

        elif fam == "gemma_local_global":
            per, nsb, tail = self._gemma_plan()

            def superblock(x, inp):
                sp, c = inp
                new_local = []
                for i in range(per):
                    lp_i = jax.tree.map(lambda a: a[i], sp["local"])
                    ci = jax.tree.map(lambda a: a[i], c["local"]) if decode else None
                    x, nc = attn_block(lp_i, cfg, x, positions,
                                       window=cfg.sliding_window,
                                       cache=ci, cache_pos=cache_pos,
                                       write_mask=write_mask)
                    new_local.append(nc)
                x, ngc = attn_block(sp["global"], cfg, x, positions, window=0,
                                    cache=c["global"] if decode else None,
                                    cache_pos=cache_pos,
                                    write_mask=write_mask)
                if decode:
                    stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_local)
                    return x, {"local": stacked, "global": ngc}
                return x, None
            f = superblock if decode else self._remat(superblock)
            x, new_super = jax.lax.scan(
                f, x, (params["super"],
                       caches["super"] if decode else None))
            new_caches = {"super": new_super} if decode else None
            if tail:
                def tailbody(x, inp):
                    lp, c = inp
                    return attn_block(lp, cfg, x, positions,
                                      window=cfg.sliding_window,
                                      cache=c, cache_pos=cache_pos,
                                      write_mask=write_mask)
                ft = tailbody if decode else self._remat(tailbody)
                x, new_tail = jax.lax.scan(
                    ft, x, (params["tail"], caches["tail"] if decode else None))
                if decode:
                    new_caches["tail"] = new_tail

        elif fam == "zamba_hybrid":
            nsb, per = self._zamba_plan()
            shared = params["shared_attn"]

            def superblock(x, inp):
                sp, c = inp
                x, nac = attn_block(shared, cfg, x, positions,
                                    cache=c["attn"] if decode else None,
                                    cache_pos=cache_pos)
                new_m = []
                for i in range(per):
                    lp_i = jax.tree.map(lambda a: a[i], sp)
                    ci = jax.tree.map(lambda a: a[i], c["mamba"]) if decode else None
                    x, nc = mamba_layer(lp_i, cfg, x, cache=ci)
                    new_m.append(nc)
                if decode:
                    stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
                    return x, {"attn": nac, "mamba": stacked}
                return x, None
            f = superblock if decode else self._remat(superblock)
            x, new_super = jax.lax.scan(
                f, x, (params["super"], caches["super"] if decode else None))
            new_caches = {"super": new_super} if decode else None

        elif fam == "vlm_cross":
            nsb, per, cross_at = self._vlm_plan()
            image_embeds = extras  # [B, n_img, D] (train) or None (decode)

            def superblock(x, inp):
                sp, c = inp
                new_selfs = []
                si = 0
                ncc = None
                for pos_in_block in range(per):
                    if pos_in_block == cross_at:
                        if decode:
                            x, _ = attn_block(sp["cross"], cfg, x, positions,
                                              cache=c["cross"],
                                              static_cache=True)
                            ncc = c["cross"]
                        else:
                            x, _ = attn_block(sp["cross"], cfg, x, positions,
                                              kv_source=image_embeds)
                    else:
                        lp_i = jax.tree.map(lambda a: a[si], sp["selfs"])
                        ci = (jax.tree.map(lambda a: a[si], c["selfs"])
                              if decode else None)
                        x, nc = attn_block(lp_i, cfg, x, positions,
                                           cache=ci, cache_pos=cache_pos,
                                           write_mask=write_mask)
                        new_selfs.append(nc)
                        si += 1
                if decode:
                    stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_selfs)
                    return x, {"selfs": stacked, "cross": ncc}
                return x, None
            f = superblock if decode else self._remat(superblock)
            x, new_super = jax.lax.scan(
                f, x, (params["super"], caches["super"] if decode else None))
            new_caches = {"super": new_super} if decode else None

        elif fam == "enc_dec":
            enc_out = extras  # encoder output [B, S_enc, D]

            def body(x, inp):
                lp, c = inp
                x, nc = attn_block(lp["self"], cfg, x, positions,
                                   cache=c["self"] if decode else None,
                                   cache_pos=cache_pos,
                                   write_mask=write_mask)
                if decode:
                    x, _ = attn_block(lp["cross"], cfg, x, positions,
                                      cache=c["cross"], static_cache=True)
                    ncc = c["cross"]
                    return x, {"self": nc, "cross": ncc}
                x, _ = attn_block(lp["cross"], cfg, x, positions,
                                  kv_source=enc_out)
                return x, None
            f = body if decode else self._remat(body)
            x, new_caches = jax.lax.scan(
                f, x, (params["layers"], caches))
        else:
            raise ValueError(fam)

        return x, new_caches, aux

    def _encode(self, params, frame_embeds):
        """Whisper encoder: bidirectional self-attention over frames."""
        cfg = self.cfg
        B, S, D = frame_embeds.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, lp):
            h = rms_norm(lp["ln1"], x, cfg.norm_eps)
            a, _ = attention(lp["attn"], cfg, h, positions, kv_source=h)
            x = x + a  # kv_source=h -> no causal mask (bidirectional)
            x = x + mlp(lp["mlp"], rms_norm(lp["ln2"], x, cfg.norm_eps))
            return x, None

        x, _ = jax.lax.scan(self._remat(body), frame_embeds.astype(cfg.compute_dtype),
                            params["encoder"])
        return rms_norm(params["enc_norm"], x, cfg.norm_eps)

    # -- public API ----------------------------------------------------------
    def forward(self, params, tokens, extras=None, caches=None,
                cache_pos=None, start_pos=None, write_mask=None):
        cfg = self.cfg
        B, T = tokens.shape
        tokens = shard(tokens, "batch", "seq")
        if start_pos is None:
            positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        else:
            positions = jnp.broadcast_to(start_pos + jnp.arange(T), (B, T))
        x = embed(params["embed"], tokens, cfg)
        if cfg.encoder is not None and extras is not None:
            extras = self._encode(params, extras)
        x, new_caches, aux = self._run_stack(params, x, positions, caches,
                                             cache_pos, train=caches is None,
                                             extras=extras,
                                             write_mask=write_mask)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        return x, new_caches, aux

    def loss(self, params, batch):
        """batch: {"tokens": [B, T] (+ "image_embeds"/"frame_embeds")}.

        The forward runs on the FULL T tokens (labels rolled left, final
        position masked) rather than on tokens[:, :-1]: an odd T-1 would
        defeat every power-of-two blocking downstream — the 512-wide
        query-chunked attention, loss token chunks, seq sharding — and
        cost a [T-1, T-1] f32 score materialization per layer
        (EXPERIMENTS.md §Perf, iteration "full-length loss").
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        extras = batch.get("image_embeds", batch.get("frame_embeds"))
        hidden, _, aux = self.forward(params, tokens, extras=extras)
        w_out = unembed_matrix(params["embed"], cfg).astype(cfg.compute_dtype)
        labels = jnp.roll(tokens, -1, axis=1)
        weights = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
        xent = chunked_softmax_xent(hidden, w_out, labels, weights=weights,
                                    layout=cfg.xent_layout)
        total = xent + (cfg.moe.aux_loss_weight * aux if cfg.moe else 0.0)
        return total, {"xent": xent, "aux": aux}

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, extras_len: int = 0):
        cfg = self.cfg
        fam = self._structure()
        W = cfg.sliding_window

        def kvc(window=0):
            return init_kv_cache(cfg, batch, max_len, window=window)

        def stack_zeros(n, tree):
            return jax.tree.map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)

        def cross_c(src_len):
            kv = (batch, src_len, cfg.num_kv_heads, cfg.head_dim_)
            return {"k": jnp.zeros(kv, cfg.compute_dtype),
                    "v": jnp.zeros(kv, cfg.compute_dtype)}

        if fam == "uniform_attn":
            return stack_zeros(cfg.num_layers, kvc(W))
        if fam == "uniform_moe":
            return stack_zeros(cfg.num_layers, kvc())
        if fam == "uniform_rwkv":
            return stack_zeros(cfg.num_layers, rw.init_rwkv_cache(cfg, batch))
        if fam == "gemma_local_global":
            per, nsb, tail = self._gemma_plan()
            sup = {"local": stack_zeros(per, kvc(W)), "global": kvc()}
            out = {"super": stack_zeros(nsb, sup)}
            if tail:
                out["tail"] = stack_zeros(tail, kvc(W))
            return out
        if fam == "zamba_hybrid":
            nsb, per = self._zamba_plan()
            sup = {"attn": kvc(),
                   "mamba": stack_zeros(per, m2.init_mamba_cache(cfg, batch))}
            return {"super": stack_zeros(nsb, sup)}
        if fam == "vlm_cross":
            nsb, per, _ = self._vlm_plan()
            sup = {"selfs": stack_zeros(per - 1, kvc(W)),
                   "cross": cross_c(extras_len or cfg.num_image_tokens)}
            return {"super": stack_zeros(nsb, sup)}
        if fam == "enc_dec":
            lay = {"self": kvc(), "cross": cross_c(extras_len or
                                                   cfg.encoder.num_frames)}
            return stack_zeros(cfg.num_layers, lay)
        raise ValueError(fam)

    def fill_cross_cache(self, params, caches, source):
        """Populate cross-attention K/V from image/encoder source."""
        cfg = self.cfg
        fam = self._structure()
        if fam == "enc_dec":
            source = self._encode(params, source)
            return _fill_scan(params["layers"], caches, cfg, source)
        if fam == "vlm_cross":
            def fill_super(sp, c):
                return {**c, "cross": cross_kv(sp["cross"]["attn"], cfg,
                                               source)}
            nsb = self._vlm_plan()[0]
            new = []
            for i in range(nsb):
                sp = jax.tree.map(lambda a: a[i], params["super"])
                ci = jax.tree.map(lambda a: a[i], caches["super"])
                new.append(fill_super(sp, ci))
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new)
            return {"super": stacked}
        return caches

    def decode_step(self, params, caches, tokens, pos, write_mask=None):
        """tokens: [B, 1]; pos: absolute position — scalar (lockstep wave
        decode) or [B] vector (per-slot continuous batching, where each
        row advances independently).  ``write_mask`` [B, 1] gates the
        cache write per row (required by the paged backend, where a dead
        row's junk write could land in another sequence's page; the
        dense backends leave it None — junk stays in the row's own
        private cache rows).  Greedy."""
        cfg = self.cfg
        pos = jnp.asarray(pos)
        start = pos if pos.ndim == 0 else pos[:, None]      # [B,1] broadcasts
        hidden, new_caches, _ = self.forward(
            params, tokens, caches=caches, cache_pos=pos, start_pos=start,
            write_mask=write_mask)
        w_out = unembed_matrix(params["embed"], cfg).astype(cfg.compute_dtype)
        logits = full_logits(hidden, w_out)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    def decode_loop(self, params, caches, cur, pos, rem, eos, *,
                    k: int, max_len: int):
        """Fused ``k``-step greedy decode: one device call emits a whole
        token *block* (packet-mode decode, DESIGN.md §6).

        A ``jax.lax.scan`` over :meth:`decode_step`, with the per-token
        retire conditions of the serving engine applied on device so the
        host syncs once per block instead of once per token:

          cur [B] int32 — last emitted token per row (prefill output or
              the previous block's tail);
          pos [B] int32 — tokens written to each row's cache so far;
          rem [B] int32 — tokens the row may still emit (0 = idle row);
          eos [B] int32 — per-row stop token (-1: never; greedy ids are
              always >= 0 so -1 can never match).

        Each step decodes the whole fixed-shape batch, then emits the
        produced token for rows still *alive*; a row dies after emitting
        its EOS, its last allowed token, or on hitting ``max_len``.
        Finished/idle rows emit -1 and stop advancing ``pos`` — their
        cache writes land on a stale slot that the next prefill
        overwrites (the same masking discipline as idle slots in the
        scalar path).  Emissions form a per-row *prefix* of the block,
        so ``n_valid = (block >= 0).sum(axis=1)`` and the row's next
        ``cur`` is ``block[i, n_valid[i]-1]``.

        Returns ``(block [B, k] int32 with -1 padding, new caches)``.
        :meth:`decode_step` is exactly the k=1 special case (one step,
        no masking needed: the engine only feeds rows that owe >= 1
        token).
        """
        eos = jnp.asarray(eos, jnp.int32)

        def body(carry, _):
            caches, cur, pos, rem, alive = carry
            # Paged backend: only alive rows may scatter into the shared
            # pool (a dense cache tolerates dead-row junk writes because
            # each row's cache rows are private; pool pages are not).
            wm = alive[:, None] if is_paged(caches) else None
            nxt, caches = self.decode_step(params, caches, cur[:, None], pos,
                                           write_mask=wm)
            emit = jnp.where(alive, nxt, -1)
            pos = jnp.where(alive, pos + 1, pos)
            rem = jnp.where(alive, rem - 1, rem)
            alive = (alive & (nxt != eos) & (rem > 0)
                     & (pos + 1 < max_len))
            cur = jnp.where(alive, nxt, cur)
            return (caches, cur, pos, rem, alive), emit

        cur = jnp.asarray(cur, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        rem = jnp.asarray(rem, jnp.int32)
        # Initial liveness mirrors the per-step mask: a row only decodes
        # if its budget, stop token, and cache extent all allow another
        # emission.  Rows the host feeds always pass (it retires
        # finished rows first); rows joining straight from an on-device
        # prefill (chunked admission, whose first token the host has not
        # seen yet) rely on the eos/max_len terms.
        alive = (rem > 0) & (cur != eos) & (pos + 1 < max_len)
        carry = (caches, cur, pos, rem, alive)
        (caches, *_), block = jax.lax.scan(body, carry, None, length=k)
        return jnp.swapaxes(block, 0, 1), caches

    @property
    def chunkable(self) -> bool:
        """Chunked zero-copy prefill needs every cache write to be
        position-indexed (attention rings / static cross caches);
        recurrent state (mamba, rwkv) folds every token into one carry
        and cannot be write-masked per position."""
        return self.cfg.ssm is None and self.cfg.rwkv is None

    @property
    def pageable(self) -> bool:
        """Paged KV residency (DESIGN.md §10) needs one uniform,
        position-indexed KV shape per layer so the whole stack shares
        one page pool: the uniform attention/moe families qualify;
        heterogeneous stacks (local:global, cross-attn, enc-dec) and
        recurrent state do not, and a sliding-window ring defeats the
        linear position->page mapping (and its O(W) residency already
        is length-bounded)."""
        return (self._structure() in ("uniform_attn", "uniform_moe")
                and not self.cfg.sliding_window)

    def prefill_chunk_into(self, params, caches, chunk, start, n_valid):
        """Chunked zero-copy prefill (DESIGN.md §9): attend one
        fixed-shape prompt chunk per admitting row and write its KV
        *directly into the (donated) batch-cache rows* — no B=1 side
        cache and no copy-into-slot dispatch afterwards.

          chunk   [B, C] int32 — per-row prompt slices (content beyond
                  ``n_valid[b]`` is ignored);
          start   [B] int32 — absolute position of each row's chunk;
          n_valid [B] int32 — real prompt tokens this chunk carries for
                  the row; 0 marks a row that is not admitting (nothing
                  is written to its cache and its output is garbage).

        The fixed [B, C] shape is what bounds the trace count: every
        prompt length streams through the same compiled function, so
        the per-bucket prefill retrace zoo collapses to one trace per
        (C, K) pair.  Returns ``(next_tok [B] int32 — the greedy token
        after each row's last valid position, new caches)``; the engine
        uses ``next_tok`` only for rows whose final chunk this was.
        """
        cfg = self.cfg
        if not self.chunkable:
            raise NotImplementedError(
                f"{cfg.name}: chunked prefill needs position-indexed "
                "caches; recurrent state cannot be write-masked")
        B, C = chunk.shape
        start = jnp.asarray(start, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        write_mask = jnp.arange(C)[None, :] < n_valid[:, None]      # [B, C]
        hidden, new_caches, _ = self.forward(
            params, chunk, caches=caches, cache_pos=start,
            start_pos=start[:, None], write_mask=write_mask)
        w_out = unembed_matrix(params["embed"], cfg).astype(cfg.compute_dtype)
        last = jnp.clip(n_valid - 1, 0, C - 1)                      # [B]
        last_h = hidden[jnp.arange(B), last][:, None]               # [B,1,D]
        logits = full_logits(last_h, w_out)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    def chunked_block(self, params, caches, chunk, start, n_valid,
                      cur, pos, rem, eos, *, k: int, max_len: int):
        """One Sarathi-style fused admission+decode dispatch: stream a
        prompt chunk into the admitting rows of the batch cache
        (:meth:`prefill_chunk_into`), then advance the decoding rows
        ``k`` steps (:meth:`decode_loop`) — one device call and one
        host fetch cover both the chunk's next-token vector and the
        [B, k] token block, so admission costs zero extra host syncs.

        A row whose FINAL chunk rides this dispatch (``n_valid > 0`` and
        ``rem > 0`` — the engine sets ``rem`` to the row's generation
        budget minus the prefill token) JOINS the decode block in the
        same dispatch: its ``cur`` is replaced by the chunk's on-device
        next token, so admission costs zero turnaround dispatches —
        prefill output feeds decode without ever visiting the host.

        Ordering matters: the chunk lands first, so the idle-row writes
        of the decode scan (rows with ``rem == 0`` emit -1 but still
        touch their ``pos`` slot) fall on the *post-chunk* extent of a
        streaming row — a slot the next chunk or the row's own first
        decode step overwrites before it is ever attended.
        """
        next_tok, caches = self.prefill_chunk_into(params, caches, chunk,
                                                   start, n_valid)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        rem = jnp.asarray(rem, jnp.int32)
        joins = (n_valid > 0) & (rem > 0)
        cur = jnp.where(joins, next_tok, jnp.asarray(cur, jnp.int32))
        block, caches = self.decode_loop(params, caches, cur, pos, rem, eos,
                                         k=k, max_len=max_len)
        return next_tok, block, caches

    def prefill(self, params, tokens, max_len, extras=None):
        """Process a prompt, producing a filled cache + next token."""
        cfg = self.cfg
        B, T = tokens.shape
        caches = self.init_cache(B, max_len,
                                 extras_len=extras.shape[1] if extras is not None else 0)
        if extras is not None or cfg.encoder is not None:
            caches = self.fill_cross_cache(params, caches, extras)
        hidden, new_caches, _ = self.forward(params, tokens, caches=caches,
                                             cache_pos=jnp.int32(0),
                                             start_pos=jnp.int32(0))
        w_out = unembed_matrix(params["embed"], cfg).astype(cfg.compute_dtype)
        logits = full_logits(hidden[:, -1:], w_out)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_caches


def prefix_chunk_hashes(tokens, chunk_tokens: int):
    """Chained hashes of the chunk-aligned prefixes of a token stream.

    Returns one digest per *full* chunk: ``out[d]`` identifies the
    prefix ``tokens[:(d + 1) * chunk_tokens]``, with each chunk's hash
    folding in its predecessor's so equal digests imply equal whole
    prefixes (not just equal chunks).  The engine hashes the bucketed,
    LEFT-PADDED prompt stream — padding is part of the content, which
    makes "same digest" exactly the condition under which two sequences'
    KV pages are interchangeable: causal attention over identical tokens
    at identical absolute positions (DESIGN.md §11).

    Host-side and model-free on purpose: the digest keys *which prefill
    dispatches can be skipped*, so it must be computable before any
    device work for the request exists.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out = []
    h = hashlib.blake2b(str(chunk_tokens).encode(), digest_size=16)
    for d in range(len(toks) // chunk_tokens):
        h = h.copy()
        h.update(toks[d * chunk_tokens:(d + 1) * chunk_tokens].tobytes())
        out.append(int.from_bytes(h.digest(), "little"))
    return out


def _fill_scan(layers, caches, cfg, source):
    """enc-dec: fill cross K/V via scan over stacked decoder layers."""
    def body(_, inp):
        lp, c = inp
        kv = cross_kv(lp["cross"]["attn"], cfg, source)
        return None, {**c, "cross": kv}
    _, new = jax.lax.scan(body, None, (layers, caches))
    return new


def build_model(cfg: ModelConfig, remat_policy: str = "nothing") -> Model:
    return Model(cfg, remat_policy=remat_policy)
