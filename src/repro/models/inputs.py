"""input_specs(): shape/dtype stand-ins for every model input.

For dry-runs these are ``jax.ShapeDtypeStruct``s (no allocation); for smoke
tests / examples they are concrete random arrays.  Modality frontends are
stubs per the assignment: VLM cells get precomputed patch embeddings,
whisper cells get precomputed audio-frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.encoder is not None:
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.d_model), cfg.compute_dtype)
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        specs["extras"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.encoder is not None:
        specs["extras"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.d_model), cfg.compute_dtype)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model) -> Dict[str, Any]:
    """Inputs for serve_step: one new token, KV cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "caches": caches,
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def concrete(specs, rng: Optional[jax.Array] = None, vocab: int = 256):
    """Materialize a spec tree with random (token) / normal (float) data."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, vocab,
                                          dtype=leaf.dtype))
        else:
            out.append(jax.random.normal(k, leaf.shape, jnp.float32)
                       .astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
