"""Model/run configuration dataclasses (single source of truth for archs)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64              # mamba2 N
    head_dim: int = 64               # mamba2 P
    num_heads: int = 0               # derived if 0: d_inner // head_dim
    expand: int = 2                  # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 256                 # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 256
    decay_lora: int = 64             # rank of the data-dependent decay MLP


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    input_specs() provides precomputed frame embeddings."""
    num_layers: int
    num_frames: int = 1500           # whisper: 30s audio -> 1500 frames


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention features
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0          # window size for local layers (0 = none)
    local_global_ratio: int = 0      # gemma3: N local layers per 1 global
    attn_logit_softcap: float = 0.0

    # hybrid/ssm/moe/vlm/enc-dec extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_every: int = 0              # zamba2: shared attn block every N layers
    cross_attn_every: int = 0        # vlm: cross-attn layer every N layers
    num_image_tokens: int = 0        # vlm stub frontend size
    encoder: Optional[EncoderConfig] = None

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # params/compute dtype

    # sharding rule overrides for this arch (merged over DEFAULT_RULES)
    mesh_rules: Optional[Dict[str, object]] = None
    # whether this arch supports the 500k-token decode shape
    supports_long_context: bool = False
    # Cross-entropy chunk layout: "flat" reshapes to [B*T] token chunks
    # (best for giant vocabs sharded over model — gemma3/arctic); then
    # "batched" keeps [B, chunk] so batch/seq sharding survives the scan
    # (best for small-vocab archs under DP/SP — measured in §Perf).
    xent_layout: str = "flat"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim_
        n = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv is not None:
            per_layer = 4 * d * d + 2 * d * self.d_ff + 3 * d * self.rwkv.decay_lora
            n += L * per_layer
            return n
        attn = (self.num_heads + 2 * self.num_kv_heads) * d * hd + self.num_heads * hd * d
        if self.ssm is not None:
            ss = self.ssm
            d_in = ss.expand * d
            nh = ss.num_heads or d_in // ss.head_dim
            mamba = d * (2 * d_in + 2 * ss.state_dim + nh) + d_in * d + d_in * ss.conv_kernel
            n += L * (mamba + 2 * d * self.d_ff)  # zamba2 blocks have MLPs
            n += attn  # one shared attention block
            return n
        if self.moe is not None:
            mo = self.moe
            ffn = mo.num_experts * 3 * d * mo.d_ff_expert + d * mo.num_experts
            if mo.dense_residual:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        n += L * (attn + ffn)
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            n += n_cross * ((self.num_heads + 2 * self.num_kv_heads) * d * hd
                            + self.num_heads * hd * d)
        if self.encoder is not None:
            n += self.encoder.num_layers * (attn + ffn)
            n += L * attn  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        mo = self.moe
        total = self.param_count()
        inactive = L * (mo.num_experts - mo.top_k) * 3 * d * mo.d_ff_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
