"""Mamba2 (SSD) block — the zamba2 hybrid's sequence mixer.

in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD recurrence via
the shared chunked-decay-linear-attention primitive (scalar decay per head,
ngroups=1); gated RMSNorm; out_proj.  Decode carries (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamBuilder, rms_norm
from repro.models.linear_attention import (
    decay_linear_attention_chunked, decay_linear_attention_scan)
from repro.parallel.sharding import Axes, shard

MAMBA_CLAMP = 1.25  # per-step log-decay clamp (fits f32 with chunk 64)


def _dims(cfg: ModelConfig):
    ss = cfg.ssm
    d_in = ss.expand * cfg.d_model
    nh = ss.num_heads or d_in // ss.head_dim
    return ss, d_in, nh


def mamba2_params(make: ParamBuilder, cfg: ModelConfig) -> Dict[str, Any]:
    ss, d_in, nh = _dims(cfg)
    d, N = cfg.d_model, ss.state_dim
    m = make.scope("mamba2")
    # projection order: z (d_in) | x (d_in) | B (N) | C (N) | dt (nh)
    return {
        "in_proj": m("in_proj", (d, 2 * d_in + 2 * N + nh),
                     Axes("embed", "mlp"), fan_in=d),
        "conv_w": m("conv_w", (ss.conv_kernel, d_in + 2 * N),
                    Axes("conv_kernel", "mlp"), scale=ss.conv_kernel ** -0.5),
        "conv_b": m("conv_b", (d_in + 2 * N,), Axes("mlp"), zero=True),
        "a_log": m("a_log", (nh,), Axes("heads"), scale=1.0),
        "dt_bias": m("dt_bias", (nh,), Axes("heads"), scale=1.0),
        "d_skip": m("d_skip", (nh,), Axes("heads"), scale=1.0),
        "norm": m("norm", (d_in,), Axes("mlp"), zero=False, scale=1.0),
        "out_proj": m("out_proj", (d_in, d), Axes("mlp", "embed"), fan_in=d_in),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    ss, d_in, nh = _dims(cfg)
    N = ss.state_dim
    z = proj[..., :d_in]
    xBC = proj[..., d_in:2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _ssd(p, cfg, x_heads, Bmat, Cmat, dt, initial_state, chunked: bool):
    """x_heads [B,T,nh,hd], Bmat/Cmat [B,T,N], dt [B,T,nh] (post-softplus)."""
    ss, d_in, nh = _dims(cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # [nh], < 0
    ld = dt * a[None, None, :]                               # [B,T,nh] log decay
    ld = jnp.broadcast_to(ld[..., None], ld.shape + (ss.state_dim,))
    q = jnp.broadcast_to(Cmat[:, :, None, :],
                         Cmat.shape[:2] + (nh, ss.state_dim))
    k = jnp.broadcast_to(Bmat[:, :, None, :],
                         Bmat.shape[:2] + (nh, ss.state_dim))
    v = x_heads * dt[..., None]                              # fold dt into input
    fn = decay_linear_attention_chunked if chunked else decay_linear_attention_scan
    kwargs = dict(chunk=ss.chunk) if chunked else {}
    y, S = fn(q, k, v, ld, u=None, initial_state=initial_state,
              decay_at_readout=True, clamp=MAMBA_CLAMP, **kwargs)
    y = y + x_heads * p["d_skip"].astype(y.dtype)[None, None, :, None]
    return y, S


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    ss, d_in, nh = _dims(cfg)
    dtype = dtype or cfg.compute_dtype
    return {
        "conv": jnp.zeros((batch, ss.conv_kernel - 1, d_in + 2 * ss.state_dim),
                          dtype),
        "ssm": jnp.zeros((batch, nh, ss.state_dim, ss.head_dim), jnp.float32),
    }


def mamba2_block(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
                 cache: Optional[Dict[str, jax.Array]] = None,
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: [B,T,D] -> ([B,T,D], new_cache)."""
    ss, d_in, nh = _dims(cfg)
    B, T, D = x.shape
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"])
    proj = shard(proj, "batch", "seq", "mlp")
    z, xBC, dt = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))

    new_cache = None
    if cache is None:
        pad = jnp.zeros((B, ss.conv_kernel - 1, xBC.shape[-1]), xBC.dtype)
        xBC_seq = jnp.concatenate([pad, xBC], axis=1)
    else:
        xBC_seq = jnp.concatenate([cache["conv"], xBC], axis=1)
        new_conv = xBC_seq[:, -(ss.conv_kernel - 1):]
    # Causal depthwise conv (kernel k): sum of k shifted slices.
    conv = sum(xBC_seq[:, i:i + T] * p["conv_w"][i][None, None, :]
               for i in range(ss.conv_kernel))
    xBC = jax.nn.silu(conv + p["conv_b"][None, None, :])

    x_in = xBC[..., :d_in].reshape(B, T, nh, ss.head_dim)
    Bmat = xBC[..., d_in:d_in + ss.state_dim]
    Cmat = xBC[..., d_in + ss.state_dim:]

    initial = cache["ssm"] if cache is not None else None
    chunked = cache is None and T % ss.chunk == 0
    y, S = _ssd(p, cfg, x_in, Bmat, Cmat, dt, initial, chunked)
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": S}

    y = y.reshape(B, T, d_in).astype(x.dtype)
    # Gated RMSNorm (mamba2-style): norm(y * silu(z)).
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return shard(out, "batch", "seq", "embed"), new_cache
