"""The jitted train/serve step functions and their sharding plumbing."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.parallel import sharding as shlib
from repro.train.optimizer import AdamW, zero_shard_spec


def make_train_step(model: Model, opt: AdamW, microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` splits the batch's leading dim and accumulates
    gradients through a ``lax.scan`` — activation memory scales with the
    microbatch, not the global batch (the standard fit-the-chip lever;
    see EXPERIMENTS.md §Perf for measured peak reductions).  The scan is
    sequential per device, so no collective schedule changes.
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), metrics

            (grads, loss), metrics = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        new_params, new_state, opt_metrics = opt.update(grads, opt_state,
                                                        params)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_state, metrics

    return train_step


def make_decode_step(model: Model):
    """serve_step: one new token against a filled KV cache."""

    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    return serve_step


def make_prefill(model: Model, max_len: int):
    def prefill(params, tokens, extras=None):
        return model.prefill(params, tokens, max_len, extras=extras)

    return prefill


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------
def param_shardings(model: Model, mesh) -> Any:
    axes = model.param_axes()
    return shlib.shardings_tree(axes)


def opt_state_shardings(model: Model, opt: AdamW, mesh, params_abs) -> Any:
    """m/v get the params' spec + ZeRO data axis on a divisible dim."""
    axes = opt.state_axes(model.param_axes())
    specs = shlib.specs_tree(axes)

    def apply_zero(spec, leaf):
        return NamedSharding(mesh, zero_shard_spec(spec, leaf.shape, mesh))

    state_abs = jax.eval_shape(opt.init, params_abs)
    return jax.tree.map(apply_zero, specs, state_abs)


def batch_shardings(mesh, batch_specs) -> Any:
    """Shard the leading (batch) dim over pod+data when divisible."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % bsz or not batch_axes:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(batch_axes, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_specs)


def cache_shardings(mesh, cache_abs, cfg) -> Any:
    """KV caches: batch over pod+data when divisible, else seq over model.

    Cache leaves are [layers?, B, S, kv, hd]-like; we shard the largest
    divisible dim: prefer the batch dim, fall back to the longest dim over
    'model' (long-context single-sample decode).
    """
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    msz = mesh.shape["model"]

    def one(leaf):
        entries = [None] * leaf.ndim
        dims = list(leaf.shape)
        # Heuristic: dims equal to known batch size get batch axes; the
        # largest remaining dim divisible by model size gets 'model'.
        for i, d in enumerate(dims):
            if batch_axes and d % bsz == 0 and d >= bsz and entries[i] is None:
                entries[i] = batch_axes
                break
        order = sorted(range(leaf.ndim), key=lambda i: -dims[i])
        for i in order:
            if entries[i] is None and dims[i] % msz == 0 and dims[i] >= msz:
                entries[i] = "model"
                break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, cache_abs)
