"""Checkpointing: atomic on-disk snapshots + lock-free async writer.

Fault-tolerance contract (1000+ node scale):
  * **Atomicity** — a checkpoint directory appears only complete: leaves
    are written to ``<dir>.tmp`` and the directory is ``rename``d into
    place (POSIX atomic), so a node failure mid-save never corrupts the
    restore point.
  * **Integrity** — a manifest records every leaf's path/shape/dtype and
    a CRC32; ``restore`` verifies before handing state to the trainer.
  * **Async, lock-free** — the trainer *publishes* a snapshot through an
    NBW versioned cell (never blocks the step loop — the paper's
    Non-blocking property) and a writer thread drains it.  If saving is
    slower than publishing, intermediate versions are skipped (NBW state
    semantics: the reader always takes the freshest value), which is the
    correct policy for checkpoints.
  * **GC** — keep the newest ``keep`` checkpoints.

Layout: ``<root>/step_<n>/{manifest.json, leaf_000.npy, ...}``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import numpy as np

from repro.core import nbw


def _flatten_with_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(root: os.PathLike, step: int, state: Any, keep: int = 3) -> Path:
    """Synchronous atomic save of a pytree."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten_with_paths(state)
    manifest: Dict[str, Any] = {"step": step, "treedef": str(treedef),
                                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        name = f"leaf_{i:05d}.npy"
        np.save(tmp / name, arr)
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)          # atomic publish
    _gc(root, keep)
    return final


def _gc(root: Path, keep: int) -> None:
    ckpts = sorted(p for p in root.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in ckpts[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: os.PathLike) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(root: os.PathLike, template: Any,
            step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (verifies CRC + shape)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    t_leaves, treedef = _flatten_with_paths(template)
    if len(manifest["leaves"]) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template "
            f"has {len(t_leaves)} (architecture mismatch?)")
    out = []
    for entry, tmpl in zip(manifest["leaves"], t_leaves):
        arr = np.load(d / entry["name"])
        if arr.dtype.kind == "V":
            # extension dtypes (bfloat16, float8) round-trip through .npy
            # as raw void records; reinterpret via the manifest dtype.
            arr = arr.view(np.dtype(entry["dtype"]))
        if zlib.crc32(arr.tobytes()) != entry["crc32"]:
            raise IOError(f"CRC mismatch in {d / entry['name']}")
        want_shape = tuple(np.shape(tmpl))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{entry['name']}: shape {arr.shape} != "
                             f"template {want_shape}")
        out.append(jnp.asarray(arr))
    return step, jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """NBW-published snapshots drained by a daemon writer thread.

    trainer:  ckpt.publish(step, state)     # O(refcount bump), never blocks
    writer:   spins on the NBW cell, saves newest unseen version.
    """

    def __init__(self, root: os.PathLike, keep: int = 3,
                 poll_s: float = 0.01):
        self.root = Path(root)
        self.keep = keep
        self._cell = nbw.HostNBW(depth=2)
        self._stop = threading.Event()
        self._last_saved_version = -1
        self._poll_s = poll_s
        self._errors: list = []
        self._saved_steps: list = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def publish(self, step: int, state: Any) -> None:
        """Hand a snapshot to the writer.  jax.Arrays are immutable, so
        publishing is reference-passing — no copy, no block."""
        self._cell.write((step, state))

    def _run(self) -> None:
        while not self._stop.is_set():
            status, value = self._cell.try_read()
            if status == nbw.OK and value is not None \
                    and self._cell.version > self._last_saved_version:
                version = self._cell.version
                step, state = value
                try:
                    save(self.root, step, state, keep=self.keep)
                    self._saved_steps.append(step)
                except Exception as e:  # noqa: BLE001 — surfaced via .errors
                    self._errors.append(e)
                self._last_saved_version = version
            else:
                time.sleep(self._poll_s)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until the newest published snapshot is on disk."""
        deadline = time.monotonic() + timeout
        while (self._cell.version > self._last_saved_version
               and time.monotonic() < deadline):
            time.sleep(self._poll_s)

    def close(self) -> None:
        self.drain()
        self._stop.set()
        self._thread.join(timeout=10)
        if self._errors:
            raise self._errors[0]

    @property
    def saved_steps(self):
        return list(self._saved_steps)
