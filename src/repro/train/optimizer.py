"""AdamW with optional 8-bit (block-quantized) first/second moments and
ZeRO-style optimizer-state sharding.

Distributed-optimization tricks for 1000+ node scale:

  * ``state_dtype="int8"`` — blockwise-quantized m/v (absmax per row) cut
    optimizer HBM 8x; required to fit arctic-480B on 16 GB chips.
  * ZeRO-1: optimizer-state specs get the ``data`` axis appended on the
    first divisible dim, so m/v are sharded over data *and* model.  GSPMD
    inserts the reduce-scatter/all-gather pair automatically.
  * Global-norm clipping and cosine schedule with linear warmup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import Axes


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"     # "float32" | "int8"
    quant_block: int = 256           # (row-wise absmax; block kept for doc)


# ---------------------------------------------------------------------------
# Blockwise int8 quantization (row absmax over the last axis).
# ---------------------------------------------------------------------------
def _quantize(x: jax.Array) -> Dict[str, jax.Array]:
    if x.ndim == 0:
        x = x[None]
        squeeze = True
    else:
        squeeze = False
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    if squeeze:
        q, scale = q[0], scale[0]
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(s: Dict[str, jax.Array]) -> jax.Array:
    q, scale = s["q"], s["scale"]
    if q.ndim == 0:
        return q.astype(jnp.float32) * scale
    return q.astype(jnp.float32) * scale


class AdamW:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    # -- state ----------------------------------------------------------------
    def init(self, params) -> Dict[str, Any]:
        def zeros_like_state(p):
            z = jnp.zeros(p.shape, jnp.float32)
            if self.cfg.state_dtype == "int8":
                return _quantize(z)
            return z

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_like_state, params),
            "v": jax.tree.map(zeros_like_state, params),
        }

    def state_axes(self, param_axes) -> Dict[str, Any]:
        """Axes metadata tree matching init() structure (for sharding)."""
        def per_param(a: Axes):
            if self.cfg.state_dtype == "int8":
                names = a.names if a.names else (None,)
                scale_names = names[:-1] + (None,)
                return {"q": Axes(*names), "scale": Axes(*scale_names)}
            return a

        m = jax.tree.map(per_param, param_axes,
                         is_leaf=lambda x: isinstance(x, Axes))
        return {"step": Axes(), "m": m, "v": m}

    # -- schedule ---------------------------------------------------------------
    def schedule(self, step: jax.Array) -> jax.Array:
        c = self.cfg
        warm = jnp.minimum(step / max(c.warmup_steps, 1), 1.0)
        t = jnp.clip((step - c.warmup_steps)
                     / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return c.lr * warm * (0.1 + 0.9 * cos)

    # -- update -------------------------------------------------------------
    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1

        # Global-norm clip (f32 accumulation).
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))

        lr = self.schedule(step)
        b1c = 1 - c.b1 ** step.astype(jnp.float32)
        b2c = 1 - c.b2 ** step.astype(jnp.float32)
        quant = c.state_dtype == "int8"

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_f = _dequantize(m) if quant else m
            v_f = _dequantize(v) if quant else v
            m_f = c.b1 * m_f + (1 - c.b1) * g
            v_f = c.b2 * v_f + (1 - c.b2) * jnp.square(g)
            mhat = m_f / b1c
            vhat = v_f / b2c
            delta = mhat / (jnp.sqrt(vhat) + c.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            new_m = _quantize(m_f) if quant else m_f
            new_v = _quantize(v_f) if quant else v_f
            return new_p, new_m, new_v

        is_state_leaf = (lambda x: isinstance(x, dict) and "q" in x) if quant \
            else None
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"], is_leaf=is_state_leaf)
        flat_v = jax.tree.leaves(state["v"], is_leaf=is_state_leaf)
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_params, {"step": step, "m": new_m, "v": new_v}, {
            "grad_norm": gnorm, "lr": lr}


def zero_shard_spec(spec: P, shape: Tuple[int, ...], mesh,
                    zero_axis: str = "data") -> P:
    """Append the ZeRO axis to the first divisible, unsharded dim."""
    if zero_axis not in mesh.axis_names:
        return spec
    size = mesh.shape[zero_axis]
    used = set()
    for e in spec:
        if isinstance(e, str):
            used.add(e)
        elif isinstance(e, tuple):
            used.update(e)
    if zero_axis in used:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % size == 0 and dim >= size:
            entries[i] = zero_axis
            return P(*entries)
    return spec
