"""Training loop: checkpoint/restart, straggler mitigation, elastic notes.

The trainer composes every lock-free substrate piece:
  data:        lock-free MPSC pipeline (repro.data.pipeline)
  step:        jitted train_step (pjit/GSPMD sharding)
  checkpoint:  NBW-published async writer (repro.train.checkpoint)
  telemetry:   NBW scalar cells (step/loss) any monitor thread can poll

Fault tolerance at 1000+ nodes:
  * restart — ``Trainer(..., resume=True)`` restores the newest intact
    checkpoint (atomic dirs + CRC manifests make "intact" well-defined).
  * straggler mitigation — per-step wall time feeds an EMA; steps slower
    than ``straggler_factor``× the EMA are counted and surfaced in
    metrics.  On a real fleet this signal drives hot-spare swap-in; here
    it drives the synchronous-vs-async data-feed decision and is asserted
    on in tests.
  * elastic scaling — state is stored mesh-agnostically (host pytrees);
    ``Trainer.remesh(new_mesh)`` re-jits the step and lets GSPMD reshard
    on the next dispatch, so the same checkpoint restores onto a
    different device count (see tests/test_trainer.py::test_remesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nbw
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_beta: float = 0.9
    async_checkpoint: bool = True


class Trainer:
    def __init__(self, model, opt: AdamW, cfg: TrainerConfig,
                 rng: Optional[jax.Array] = None, resume: bool = False,
                 mesh=None, shardings: Optional[tuple] = None):
        self.model, self.opt, self.cfg = model, opt, cfg
        self.mesh = mesh
        self._mk_step(shardings)

        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = model.init(rng)
        self.opt_state = opt.init(self.params)
        self.step = 0

        if resume:
            latest = ckpt_lib.latest_step(cfg.ckpt_dir)
            if latest is not None:
                self.step, (self.params, self.opt_state) = ckpt_lib.restore(
                    cfg.ckpt_dir,
                    (self.params, self.opt_state))

        self.ckpt = (ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
                     if cfg.async_checkpoint else None)
        # NBW telemetry cells: monitors read without locking the loop.
        self.telemetry = {"step": nbw.HostNBW(), "loss": nbw.HostNBW()}
        self._ema_dt: Optional[float] = None
        self.straggler_steps = 0
        self.history: list = []

    # -- step function --------------------------------------------------------
    def _mk_step(self, shardings):
        step_fn = make_train_step(self.model, self.opt)
        if shardings is not None:
            p_sh, o_sh, b_sh = shardings
            self._step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh, None),
                                 donate_argnums=(0, 1))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0, 1))

    def remesh(self, mesh, shardings: Optional[tuple] = None) -> None:
        """Elastic scale: re-jit for a new mesh; state reshards on next
        dispatch (host state is mesh-agnostic)."""
        self.mesh = mesh
        self.params = jax.device_get(self.params)
        self.opt_state = jax.device_get(self.opt_state)
        self._mk_step(shardings)

    # -- loop -----------------------------------------------------------------
    def fit(self, batches: Iterable[Dict[str, np.ndarray]], steps: int,
            on_metrics: Optional[Callable[[int, Dict], None]] = None):
        it = iter(batches)
        target = self.step + steps
        while self.step < target:
            # Time the whole iteration: a stalled data feed is a straggler
            # symptom just like a slow device.
            t0 = time.monotonic()
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])  # sync point = step boundary
            dt = time.monotonic() - t0
            self.step += 1

            # straggler detection (EMA of step wall time); the first step
            # is excluded — it pays jit compilation and would poison the EMA
            if self.step == 1:
                pass
            elif self._ema_dt is None:
                self._ema_dt = dt
            else:
                if dt > self.cfg.straggler_factor * self._ema_dt:
                    self.straggler_steps += 1
                b = self.cfg.ema_beta
                self._ema_dt = b * self._ema_dt + (1 - b) * dt

            self.telemetry["step"].write(self.step)
            self.telemetry["loss"].write(loss)
            if self.step % self.cfg.log_every == 0 or self.step == target:
                self.history.append(
                    {"step": self.step, "loss": loss, "dt_s": dt,
                     "grad_norm": float(metrics["grad_norm"]),
                     "stragglers": self.straggler_steps})
                if on_metrics:
                    on_metrics(self.step, self.history[-1])
            if self.step % self.cfg.ckpt_every == 0:
                self.save()
        return self.history

    # -- checkpointing --------------------------------------------------------
    def save(self) -> None:
        state = (self.params, self.opt_state)
        if self.ckpt is not None:
            self.ckpt.publish(self.step, state)
        else:
            ckpt_lib.save(self.cfg.ckpt_dir, self.step, state,
                          keep=self.cfg.keep)

    def close(self) -> None:
        self.save()
        if self.ckpt is not None:
            self.ckpt.close()
