"""Quickstart: build a model, take a train step, generate tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]

Uses the smoke-size config so it runs on a laptop CPU in seconds; the
same code paths scale to the full configs on a TPU mesh (see
repro/launch/dryrun.py for proof every full config compiles at 512
chips).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models.model import build_model
from repro.train.optimizer import AdamW, OptConfig
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    args = ap.parse_args(argv)

    # 1. build a model from the registry
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.2f}M params ({cfg.family})")

    # 2. one jitted train step
    opt = AdamW(OptConfig(lr=1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((2, cfg.num_image_tokens,
                                           cfg.d_model), cfg.compute_dtype)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros((2, cfg.encoder.num_frames,
                                           cfg.d_model), cfg.compute_dtype)
    params, opt_state, metrics = step(params, opt_state, batch)
    print(f"train step: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.2f}")

    # 3. prefill + greedy decode
    extras = batch.get("image_embeds", batch.get("frame_embeds"))
    tok, caches = model.prefill(params, tokens[:, :8], max_len=32,
                                extras=extras)
    out = [int(tok[0])]
    for i in range(8, 14):
        tok, caches = model.decode_step(params, caches, tok[:, None],
                                        jnp.int32(i))
        out.append(int(tok[0]))
    print(f"generated token ids: {out}")
    return out


if __name__ == "__main__":
    main()
