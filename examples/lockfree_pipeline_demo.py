"""The paper's experiment at device level: lock-based (barrier) vs
lock-free (NBB ring) pipeline exchange on an 8-device mesh.

    PYTHONPATH=src python examples/lockfree_pipeline_demo.py

Prints per-schedule collective bytes from the compiled HLO (hardware-
independent — this ratio is what transfers to TPU) plus CPU wall time,
and verifies all schedules compute identical results.
"""
import os

# must precede jax import: fork 8 host devices for a real mesh
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import pipeline_apply, pipeline_reference


def main():
    mesh = jax.make_mesh((8,), ("stage",))
    S, M, B, D = 8, 16, 8, 256

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, D, D), jnp.float32) * 0.1}
    mbs = jax.random.normal(jax.random.fold_in(key, 1), (M, B, D),
                            jnp.float32)
    want = pipeline_reference(stage_fn, params, mbs, S)

    import re
    print(f"{'schedule':10} {'collective bytes':>18} {'ms/call':>8}  match")
    for schedule in ("barrier", "nbb", "nbb2"):
        f = jax.jit(lambda p, m, s=schedule: pipeline_apply(
            stage_fn, p, m, mesh, axis="stage", schedule=s))
        compiled = f.lower(params, mbs).compile()
        coll = 0
        for line in compiled.as_text().splitlines():
            mm = re.search(r"=\s+f32\[([\d,]+)\]\S*\s+(all-gather|"
                           r"collective-permute|all-reduce)\(", line)
            if mm:
                n = 1
                for d in mm.group(1).split(","):
                    n *= int(d)
                coll += 4 * n
        out = f(params, mbs)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(10):
            out = f(params, mbs)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 10
        ok = np.allclose(np.asarray(out)[-1], np.asarray(want), atol=1e-5)
        print(f"{schedule:10} {coll:18,} {dt * 1e3:8.1f}  {ok}")
    print("\nbarrier = the reference MCAPI global lock (everyone exchanges "
          "with everyone);\nnbb = the paper's lock-free ring (point-to-point"
          " only). Fewer collective\nbytes at identical results is the "
          "paper's 25x, restated for TPU meshes.")


if __name__ == "__main__":
    main()
