"""Serve a small model with batched, *streamed* concurrent requests.

Demonstrates the handle-based session API end to end: three client
threads connect sessions to the lock-free engine, submit through
non-blocking ``submit_i`` handles, and consume tokens via
``RequestHandle.tokens()`` while the packet-mode slot batcher (the
default ``slot_fused`` scheduler) is still decoding other sequences —
tokens are produced in fused K-step blocks on device and arrive on the
client's stream ring as bursts, but the iterator surface is unchanged.
One request is cancelled mid-decode to show the CAS cancellation path
freeing its KV pages without stopping the batcher.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import ServeEngine  # noqa: E402


def main():
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_batch=4, max_len=64,
                      n_clients=3, pool_pages=256)   # slot_fused default
    eng_thread = eng.start()

    def client(c: int) -> None:
        rng = np.random.default_rng(c)
        # Context-managed: leaving the block cancels anything in flight
        # and marks the session closed (idempotent), so a client thread
        # that dies early cannot strand engine-side state.
        with eng.connect(c) as session:
            for i in range(2):
                prompt = rng.integers(0, cfg.vocab_size, 8)
                handle = session.submit_i(prompt, max_tokens=8)
                got = []
                for pos, tok in handle.tokens(timeout_s=300):
                    got.append((pos, tok))   # delivered as decoded
                r = handle.response
                print(f"client {c} req {r.req_id}: streamed {len(got)} "
                      f"tokens ({r.fsm.state.split('_')[-1]}), "
                      f"ttft {1e3 * (r.first_token_t - r.submit_t):.0f}ms")
                assert [p for p, _ in got] == list(range(len(r.tokens_out)))

    threads = [threading.Thread(target=client, args=(c,)) for c in range(3)]
    for t in threads:
        t.start()

    # A fourth stream on client 0's session thread would break the
    # one-consumer rule, so cancel from the main thread instead: cancel()
    # is thread-safe (a single CAS) while the stream surface is not.
    session = eng.connect(2)
    for t in threads:
        t.join()
    handle = session.submit_i(np.arange(8) % cfg.vocab_size, max_tokens=48)
    time.sleep(0.05)                       # let a few decode steps run
    handle.cancel()
    r = handle.wait(timeout_s=30)
    if not r:                              # typed, falsy TimeoutStatus
        print(f"cancel demo timed out waiting for the terminal: {r}")
    else:
        print(f"cancel mid-decode -> {r.fsm.state.split('_')[-1]} after "
              f"{len(r.tokens_out)}/48 tokens; kv pool free again: "
              f"{eng.pool.free_pages() == eng.pool.n_pages}")

    eng.stop()
    eng_thread.join(timeout=10)
    print(f"engine stats: {eng.stats}")
    print(f"slot occupancy: {eng.occupancy():.2f}")
    return eng


if __name__ == "__main__":
    main()
