"""Serve a small model with batched concurrent requests (deliverable b).

Three client threads fire mixed-length requests at the lock-free engine;
the iteration-level slot batcher swaps sequences in and out of the
decode pool every step (no wave barrier) and answers over per-client
SPSC rings.  Pass ``--scheduler wave`` through ``repro.launch.serve`` to
feel the convoying baseline.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main as serve_main


def main():
    return serve_main(["--arch", "smollm-135m", "--smoke",
                       "--clients", "3", "--requests-per-client", "4",
                       "--prompt-len", "8", "--max-tokens", "8",
                       "--scheduler", "slot"])


if __name__ == "__main__":
    main()
