"""End-to-end training driver (deliverable b): train a ~100M-param model
for a few hundred steps through the full stack — lock-free data pipeline,
jitted train step, async NBW checkpointing, straggler telemetry — and
verify the loss decreases and a restart resumes exactly.

    PYTHONPATH=src python examples/train_e2e.py               # ~25M proxy, fast
    PYTHONPATH=src python examples/train_e2e.py --full-135m   # real smollm-135m

The default uses a width-reduced smollm variant so a few hundred steps
finish on CPU in minutes; --full-135m runs the real config (hours on
CPU, minutes on one TPU host).
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.models.model import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamW, OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-135m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args(argv)

    cfg = get_config("smollm-135m")
    if not args.full_135m:
        # same family/topology, ~25M params: CPU-scale "100M-class" proxy
        cfg = dataclasses.replace(cfg, name="smollm-25m", num_layers=8,
                                  d_model=384, num_heads=6, num_kv_heads=2,
                                  d_ff=1024, vocab_size=16384)
    model = build_model(cfg)
    opt = AdamW(OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps))
    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    trainer = Trainer(model, opt, tc, resume=True)
    n = sum(p.size for p in jax.tree.leaves(trainer.params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params, resume from step "
          f"{trainer.step}")

    pipe = DataPipeline(batch=args.batch, seq_len=args.seq,
                        vocab=cfg.vocab_size, nproducers=2, seed=0)
    t0 = time.monotonic()
    try:
        hist = trainer.fit(
            pipe, steps=args.steps,
            on_metrics=lambda s, m: print(
                f"step {s:4d}  loss {m['loss']:.4f}  "
                f"{m['dt_s'] * 1e3:.0f} ms/step", flush=True))
    finally:
        pipe.close()
        trainer.close()
    dt = time.monotonic() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"\n{args.steps} steps in {dt:.0f}s ({tok_s:.0f} tok/s CPU)")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"checkpoints: {ckpt_lib.latest_step(args.ckpt_dir)} (latest)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    return hist


if __name__ == "__main__":
    main()
