"""Serving-layer lock-convoy benchmark: wave vs iteration-level batching.

The paper shows that deleting the queue lock turns multicore contention
into speedup; the serving-layer analogue of the lock is the *wave
barrier* — every admitted request convoys behind the slowest sequence in
its batch.  This benchmark drives both schedulers of
:class:`repro.serve.engine.ServeEngine` through an identical
mixed-length workload (short prompts interleaved with long generations,
the worst case for convoying) and records throughput, latency
percentiles, decode-step counts, slot occupancy, and rejection stats.

Expected result (the serving Figure-8): iteration-level slot swap >=
wave throughput, with the short requests' completion latency improved
the most — they no longer wait for long generations.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
Emits:  BENCH_serve.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")


def make_workload(n_requests: int, seed: int = 0) -> List[Dict]:
    """Mixed short/long requests, deterministic.  Alternates 2-token and
    24-token generations with 4/8-token prompts so every wave pairs a
    short request with a long one — maximal convoy for the baseline."""
    rng = np.random.default_rng(seed)
    work = []
    for i in range(n_requests):
        long = i % 2 == 1
        work.append({
            "prompt": rng.integers(0, 1000, 8 if long else 4),
            "max_tokens": 24 if long else 2,
        })
    return work


def run_engine(model, params, scheduler: str, workload: List[Dict],
               max_batch: int, max_len: int, repeats: int = 2) -> Dict:
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                      n_clients=1, pool_pages=512, page_size=16,
                      intake_depth=len(workload) + 4, scheduler=scheduler)

    # Warmup: trace prefill/decode shapes outside the timed region.
    for w in workload[:2]:
        eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                   max_tokens=w["max_tokens"])
    while eng.stats["served"] + eng.stats["rejected"] < 2:
        eng.step()
    for _ in range(2):
        eng.get_response(0, timeout_s=10)

    def one_pass() -> Dict:
        for k in eng.stats:
            eng.stats[k] = 0
        t0 = time.monotonic()
        for w in workload:
            assert eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                              max_tokens=w["max_tokens"]) is not None
        while eng.stats["served"] + eng.stats["rejected"] < len(workload):
            eng.step()
        dt = time.monotonic() - t0

        lat, toks, short_lat = [], 0, []
        for _ in range(len(workload)):
            r = eng.get_response(0, timeout_s=10)
            assert r is not None
            lat.append(r.done_t - r.submit_t)
            toks += len(r.tokens_out) if r.tokens_out is not None else 0
            if r.max_tokens <= 2:
                short_lat.append(r.done_t - r.submit_t)
        lat.sort()
        short_lat.sort()
        return {
            "scheduler": scheduler,
            "wall_s": dt,
            "req_per_s": len(workload) / dt,
            "tok_per_s": toks / dt,
            "tokens_out": toks,
            "lat_ms_p50": 1e3 * lat[len(lat) // 2],
            "lat_ms_p95": 1e3 * lat[int(len(lat) * 0.95)],
            "short_req_lat_ms_p50": (1e3 * short_lat[len(short_lat) // 2]
                                     if short_lat else float("nan")),
            "decode_steps": eng.stats["decode_steps"],
            "prefills": eng.stats["prefills"],
            "served": eng.stats["served"],
            "rejected": eng.stats["rejected"],
            "slot_occupancy": eng.occupancy(),
            "kv_pool": {"n_pages": eng.pool.n_pages,
                        "free_after_drain": eng.pool.free_pages()},
        }

    # Best-of-k wall time: scheduling noise on a shared host dwarfs the
    # deterministic decode-step counts; best-of is the standard antidote.
    passes = [one_pass() for _ in range(repeats)]
    return min(passes, key=lambda r: r["wall_s"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.models.model import build_model

    n_requests = args.requests or (10 if args.quick else 12)
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = make_workload(n_requests)

    results = {}
    for sched in ("wave", "slot"):
        results[sched] = run_engine(model, params, sched, workload,
                                    max_batch=args.max_batch, max_len=96)
        r = results[sched]
        print(f"{sched:5s}: {r['wall_s']:.2f}s  {r['tok_per_s']:.1f} tok/s  "
              f"decode_steps={r['decode_steps']}  "
              f"occupancy={r['slot_occupancy']:.2f}  "
              f"p50={r['lat_ms_p50']:.0f}ms  "
              f"short-p50={r['short_req_lat_ms_p50']:.0f}ms")

    out = {
        "workload": {"n_requests": n_requests, "max_batch": args.max_batch,
                     "mix": "alternating max_tokens 2 / 24, prompts 4 / 8",
                     "arch": args.arch},
        "wave": results["wave"],
        "slot": results["slot"],
        "speedup": {
            "throughput_tok_per_s": (results["slot"]["tok_per_s"]
                                     / results["wave"]["tok_per_s"]),
            "decode_steps_saved": (results["wave"]["decode_steps"]
                                   - results["slot"]["decode_steps"]),
            "short_req_latency": (results["wave"]["short_req_lat_ms_p50"]
                                  / results["slot"]["short_req_lat_ms_p50"]),
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nslot/wave throughput: {out['speedup']['throughput_tok_per_s']:.2f}x"
          f"  short-request latency: {out['speedup']['short_req_latency']:.2f}x"
          f"  -> {args.out}")
    return out


if __name__ == "__main__":
    main()
