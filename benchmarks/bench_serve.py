"""Serving-layer lock-convoy benchmark: wave vs slot vs fused vs
chunked vs paged.

The paper shows that deleting the queue lock turns multicore contention
into speedup; the serving-layer analogue of the lock is the *wave
barrier* — every admitted request convoys behind the slowest sequence in
its batch.  This benchmark drives all four schedulers of
:class:`repro.serve.engine.ServeEngine` through an identical
mixed workload (short prompts, long generations, AND long prompts —
the worst cases for convoying and for admission stall) and records
throughput, latency percentiles, decode-step counts, slot occupancy,
and rejection stats.

Expected results: iteration-level slot swap >= wave throughput (the
serving Figure-8), with the short requests' completion latency improved
the most — they no longer wait for long generations.  The packet-mode
comparison (the serving Tables 5-7, DESIGN.md §6): ``slot_fused`` moves
the decode loop on device in K-step blocks, so ``host_syncs_per_token``
and ``ring_ops_per_token`` drop from ≈1 to ≈1/K and throughput rises
again over ``slot`` — per-exchange host overhead, not FLOPs, was the
cost.  And the admission-plane comparison (DESIGN.md §9):
``slot_chunked`` deletes the per-admission host sync and the
cache-copy dispatch and streams long prompts chunk-by-chunk inside the
decode dispatches, so ``admission_stall_steps`` drops to 0 (fused pays
one stalled step per active slot per admission) with
``cache_copy_dispatches == 0`` and ``host_syncs_per_token`` at or below
the fused baseline — all deterministic counters, immune to the
wall-clock noise of a shared host.  Finally the residency comparison
(DESIGN.md §10): ``slot_paged`` keeps chunked's dispatch discipline but
makes the page pool the device-resident KV store, so its peak
``kv_resident_bytes`` is the live pages (length-proportional) instead
of the dense O(B·max_len) batch cache and its ``kv_copy_bytes`` is 0 —
residency is established by writing int32 block-table rows.

Streaming metrics (the handle/session API): time-to-first-token is the
harvest time of token 0 (`Request.first_token_t`, when the token hits
the client's stream ring) minus submit time; inter-token latency is the
spacing of `Request.token_ts`.  The wave baseline delivers whole
responses only, so its TTFT *is* its completion latency — the gap
between slot TTFT p50 and whole-response p50 is what the streaming API
buys.

Prefix-sharing comparison (DESIGN.md §11): a chat-style workload — N
sessions that all open with the same long system prompt — runs through
``slot_paged`` with the prefix cache on and off.  With it on, every
session after the first adopts the cached prefix pages (refcount
increments + int32 block-table rows) and prefills only its own suffix:
``prefill_chunks`` collapses, peak residency counts each shared
physical page once, and the only KV bytes ever copied are the
copy-on-write pages where a session diverges inside a shared page
(``cow_copy_bytes``).  Token sequences are asserted byte-identical
cache-on vs cache-off, on this workload AND the mixed workload above.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
Emits:  BENCH_serve.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")


def make_workload(n_requests: int, seed: int = 0) -> List[Dict]:
    """Mixed long/short requests, deterministic.  Alternates 2-token and
    24-token generations with 4/8-token prompts so every wave pairs a
    short request with a long one — maximal convoy for the baseline —
    and every fourth request carries a LONG PROMPT (48 tokens, bucketed
    to 64) with a short generation: the admission-stall worst case,
    where a monolithic prefill stalls every active decode slot and the
    chunked scheduler streams it through the decode dispatches."""
    rng = np.random.default_rng(seed)
    work = []
    for i in range(n_requests):
        long_prompt = i % 4 == 2
        long_gen = not long_prompt and i % 2 == 1
        work.append({
            "prompt": rng.integers(0, 1000,
                                   48 if long_prompt else (8 if long_gen
                                                           else 4)),
            "max_tokens": 24 if long_gen else (4 if long_prompt else 2),
        })
    return work


def make_prefix_workload(n_sessions: int, system_len: int = 116,
                         user_len: int = 4, seed: int = 1) -> List[Dict]:
    """Chat-style prefix-heavy workload: every session opens with the
    SAME ``system_len``-token system prompt and appends a distinct short
    user turn (prompts bucket to 128).  With chunk_tokens=8 the deepest
    shared chunk extent lands mid-page (120 of page_size 16), so every
    hit both adopts seven whole shared pages AND copy-on-writes exactly
    the one page it diverges inside."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, 1000, system_len)
    return [{"prompt": np.concatenate([system,
                                       rng.integers(0, 1000, user_len)]),
             "max_tokens": 16} for _ in range(n_sessions)]


def run_engine(model, params, scheduler: str, workload: List[Dict],
               max_batch: int, max_len: int, repeats: int = 2,
               chunk_tokens: int = 16, prefix_cache: bool = True,
               pool_pages: int = None) -> Dict:
    from repro.serve.engine import ServeEngine

    # The dense schedulers use the pool for ACCOUNTING only, so its size
    # is pure admission headroom; for slot_paged the pool IS the device
    # KV store — give it exactly the dense batch cache's HBM budget
    # (max_batch * max_len positions) so the comparison is same-memory.
    page_size = 16
    if pool_pages is None:
        pool_pages = ((max_batch * max_len + page_size - 1) // page_size
                      if scheduler == "slot_paged" else 512)
    eng = ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                      n_clients=1, pool_pages=pool_pages,
                      page_size=page_size,
                      intake_depth=len(workload) + 4, scheduler=scheduler,
                      chunk_tokens=chunk_tokens, prefix_cache=prefix_cache)

    # Warmup: trace prefill/decode shapes outside the timed region.
    for w in workload[:2]:
        eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                   max_tokens=w["max_tokens"])
    while eng.stats["served"] + eng.stats["rejected"] < 2:
        eng.step()
    for _ in range(2):
        warm = eng.get_response(0, timeout_s=10)
        assert warm, "warmup response timed out"

    def one_pass() -> Dict:
        for k in eng.stats:
            eng.stats[k] = 0
        if eng.prefix_cache is not None:
            eng.prefix_cache.clear()    # every pass measures a cold cache
        eng.pool.reset_traffic()
        t0 = time.monotonic()
        rids = []
        for w in workload:
            submitted = eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                                   max_tokens=w["max_tokens"])
            assert submitted is not None, "intake ring full mid-benchmark"
            rids.append(submitted.req_id)
        while eng.stats["served"] + eng.stats["rejected"] < len(workload):
            eng.step()
        dt = time.monotonic() - t0

        lat, toks, short_lat, ttft, itl = [], 0, [], [], []
        seqs: Dict[int, List[int]] = {}
        for _ in range(len(workload)):
            r = eng.get_response(0, timeout_s=10)
            assert r, "response timed out"
            seqs[r.req_id] = (list(map(int, r.tokens_out))
                              if r.tokens_out is not None else [])
            lat.append(r.done_t - r.submit_t)
            # rejected/cancelled terminals never set first_token_t
            ttft.append((r.first_token_t or r.done_t) - r.submit_t)
            itl.extend(b - a for a, b in zip(r.token_ts, r.token_ts[1:]))
            toks += len(r.tokens_out) if r.tokens_out is not None else 0
            if r.max_tokens <= 2:
                short_lat.append(r.done_t - r.submit_t)
        lat.sort()
        short_lat.sort()
        ttft.sort()
        itl.sort()
        return {
            "scheduler": scheduler,
            "wall_s": dt,
            "req_per_s": len(workload) / dt,
            "tok_per_s": toks / dt,
            "tokens_out": toks,
            "lat_ms_p50": 1e3 * lat[len(lat) // 2],
            "lat_ms_p95": 1e3 * lat[int(len(lat) * 0.95)],
            "short_req_lat_ms_p50": (1e3 * short_lat[len(short_lat) // 2]
                                     if short_lat else float("nan")),
            # Streaming delivery metrics.  The wave baseline has no
            # per-token delivery, so its TTFT equals completion latency
            # (first_token_t is set at delivery) and it has no ITL.
            "ttft_ms_p50": 1e3 * ttft[len(ttft) // 2],
            "ttft_ms_p95": 1e3 * ttft[int(len(ttft) * 0.95)],
            "itl_ms_p50": (1e3 * itl[len(itl) // 2] if itl else None),
            "itl_ms_p95": (1e3 * itl[int(len(itl) * 0.95)] if itl else None),
            "decode_steps": eng.stats["decode_steps"],
            "prefills": eng.stats["prefills"],
            "served": eng.stats["served"],
            "rejected": eng.stats["rejected"],
            # Packet-mode exchange metrics (DESIGN.md §6): device->host
            # syncs and client-facing ring operations per generated
            # token — the scalar paths pay one sync per decode *step*
            # (≈ 1/batch per token), the fused path one per K-step
            # block (≈ 1/(K·batch)).
            "host_syncs": eng.stats["host_syncs"],
            "host_syncs_per_token": eng.stats["host_syncs"] / max(toks, 1),
            "ring_ops_per_token": eng.stats["ring_ops"] / max(toks, 1),
            "fused_blocks": eng.stats["fused_blocks"],
            # Admission-plane counters (DESIGN.md §9): prefill device
            # dispatches / prompt chunks materialized, cache-copy
            # dispatches (the B=1 -> batch-row copy the chunked path
            # deletes), and decode-step opportunities active slots lost
            # to serial prefills (0 for slot_chunked — chunks ride the
            # decode dispatch).
            "prefill_dispatches": eng.stats["prefill_dispatches"],
            "prefill_chunks": eng.stats["prefill_chunks"],
            "cache_copy_dispatches": eng.stats["cache_copy_dispatches"],
            "admission_stall_steps": eng.stats["admission_stall_steps"],
            "slot_occupancy": eng.occupancy(),
            "kv_pool": {"n_pages": eng.pool.n_pages,
                        "free_after_drain": eng.pool.free_pages()},
            # Residency economics (DESIGN.md §10): peak KV bytes a
            # scheduler actually held for the workload (paged: live
            # pages; dense: the whole batch cache) and the KV bytes it
            # COPIED to establish residency (paged: 0 — swap-in is an
            # int32 block-table row).
            "kv_resident_bytes_peak": (
                eng.pool.stats()["kv_resident_bytes_peak"]
                if scheduler == "slot_paged" else eng.dense_cache_bytes()),
            "kv_copy_bytes": eng.pool.stats()["kv_copy_bytes"],
            "dense_cache_bytes": eng.dense_cache_bytes(),
            # Prefix-sharing counters (DESIGN.md §11): admissions that
            # adopted cached pages, the prefill tokens those hits
            # skipped, the most physical pages ever multiply-referenced
            # at once, and the CoW share of kv_copy_bytes.
            "prefix_hits": eng.stats["prefix_hits"],
            "prefill_tokens_saved": eng.stats["prefill_tokens_saved"],
            "shared_pages_peak": eng.pool.stats()["shared_pages_peak"],
            "cow_copy_bytes": eng.pool.stats()["cow_copy_bytes"],
            "pool_pages": eng.pool.n_pages,
            # Token sequences in submission order: the byte-identity
            # gate compares these across cache on/off (stripped from the
            # JSON artifact).
            "_token_seqs": [seqs[r] for r in rids],
        }

    # Best-of-k wall time: scheduling noise on a shared host dwarfs the
    # deterministic decode-step counts; best-of is the standard antidote.
    passes = [one_pass() for _ in range(repeats)]
    return min(passes, key=lambda r: r["wall_s"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="slot_chunked prompt chunk (32 is the measured "
                         "sweet spot for this workload: half the chunk "
                         "dispatches of 16 at the same stall bound)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.models.model import build_model

    n_requests = args.requests or (10 if args.quick else 12)
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = make_workload(n_requests)

    results = {}
    for sched in ("wave", "slot", "slot_fused", "slot_chunked",
                  "slot_paged"):
        results[sched] = run_engine(model, params, sched, workload,
                                    max_batch=args.max_batch, max_len=96,
                                    chunk_tokens=args.chunk_tokens)
        r = results[sched]
        itl = (f"{r['itl_ms_p50']:.2f}" if r["itl_ms_p50"] is not None
               else "-")
        print(f"{sched:12s}: {r['wall_s']:.2f}s  {r['tok_per_s']:.1f} tok/s  "
              f"decode_steps={r['decode_steps']}  "
              f"syncs/tok={r['host_syncs_per_token']:.2f}  "
              f"ring-ops/tok={r['ring_ops_per_token']:.2f}  "
              f"prefill-disp={r['prefill_dispatches']}  "
              f"stall={r['admission_stall_steps']}  "
              f"kv-resident={r['kv_resident_bytes_peak'] // 1024}KiB  "
              f"kv-copied={r['kv_copy_bytes'] // 1024}KiB  "
              f"p50={r['lat_ms_p50']:.0f}ms  "
              f"short-p50={r['short_req_lat_ms_p50']:.0f}ms  "
              f"ttft-p50={r['ttft_ms_p50']:.0f}ms  itl-p50={itl}ms")

    # Byte-identity gate on the mixed workload: the prefix cache must
    # never change tokens, only skip dispatches.
    paged_off_mixed = run_engine(model, params, "slot_paged", workload,
                                 max_batch=args.max_batch, max_len=96,
                                 chunk_tokens=args.chunk_tokens,
                                 prefix_cache=False, repeats=1)
    mixed_identity = (results["slot_paged"]["_token_seqs"]
                      == paged_off_mixed["_token_seqs"])
    assert mixed_identity, "prefix cache changed tokens (mixed workload)"

    # Prefix-heavy chat workload: N sessions, one shared system prompt.
    # The cache-on pool is sized to what EIGHT dense-equivalent
    # sequences would hold (8 * max_len positions) — sharing must admit
    # all N concurrently on it; cache-off gets the dense-equivalent pool
    # for N so the comparison measures dispatches and residency, not
    # rejections.
    n_sessions = 8 if args.quick else 32
    prefix_len, prefix_cap = 160, 8
    pw = make_prefix_workload(n_sessions)
    shared_pool = prefix_cap * prefix_len // 16
    dense_pool = n_sessions * ((128 + 16 + 15) // 16) + 16
    pre_kw = dict(max_batch=n_sessions, max_len=prefix_len, chunk_tokens=8,
                  repeats=1 if args.quick else 2)
    pre_on = run_engine(model, params, "slot_paged", pw,
                        pool_pages=shared_pool, **pre_kw)
    pre_off = run_engine(model, params, "slot_paged", pw,
                         prefix_cache=False,
                         pool_pages=max(shared_pool, dense_pool), **pre_kw)
    assert pre_on["_token_seqs"] == pre_off["_token_seqs"], \
        "prefix cache changed tokens (prefix workload)"
    assert pre_on["served"] == n_sessions and pre_on["rejected"] == 0, \
        "sharing failed to admit every session on the shared pool"
    chunks_ratio = (pre_off["prefill_chunks"]
                    / max(pre_on["prefill_chunks"], 1))
    prefix_out = {
        "workload": {"n_sessions": n_sessions,
                     "mix": "116-token shared system prompt + 4 distinct "
                            "user tokens (bucket 128), 16 generated",
                     "chunk_tokens": 8,
                     "pool_pages_on": pre_on["pool_pages"],
                     "pool_pages_off": pre_off["pool_pages"]},
        "on": pre_on, "off": pre_off,
        "prefill_chunks_ratio": chunks_ratio,
        "prefill_tokens_saved": pre_on["prefill_tokens_saved"],
        "prefix_hits": pre_on["prefix_hits"],
        "shared_pages_peak": pre_on["shared_pages_peak"],
        "cow_copy_bytes": pre_on["cow_copy_bytes"],
        "cow_is_only_copy_traffic": (pre_on["kv_copy_bytes"]
                                     == pre_on["cow_copy_bytes"]),
        "kv_resident_peak_ratio": (pre_off["kv_resident_bytes_peak"]
                                   / max(pre_on["kv_resident_bytes_peak"],
                                         1)),
        "tokens_identical": True,
        "mixed_tokens_identical": mixed_identity,
    }

    slot, wave = results["slot"], results["wave"]
    fused, chunked = results["slot_fused"], results["slot_chunked"]
    paged = results["slot_paged"]
    out = {
        "workload": {"n_requests": n_requests, "max_batch": args.max_batch,
                     "mix": "alternating max_tokens 2 / 24 (prompts 4 / 8) "
                            "with a 48-token long prompt every 4th request",
                     "chunk_tokens": args.chunk_tokens,
                     "arch": args.arch},
        "wave": wave,
        "slot": slot,
        "slot_fused": fused,
        "slot_chunked": chunked,
        "slot_paged": paged,
        "prefix_sharing": prefix_out,
        "speedup": {
            "throughput_tok_per_s": (slot["tok_per_s"] / wave["tok_per_s"]),
            "decode_steps_saved": (wave["decode_steps"]
                                   - slot["decode_steps"]),
            "short_req_latency": (wave["short_req_lat_ms_p50"]
                                  / slot["short_req_lat_ms_p50"]),
            # Streaming wins: first token vs waiting for the whole
            # response (same scheduler), and vs the wave baseline.
            "ttft_vs_whole_response": (slot["lat_ms_p50"]
                                       / slot["ttft_ms_p50"]),
            "ttft_vs_wave": wave["ttft_ms_p50"] / slot["ttft_ms_p50"],
            "ttft_better_than_whole_response": (slot["ttft_ms_p50"]
                                                < slot["lat_ms_p50"]),
            # Packet-mode decode wins (DESIGN.md §6): fused blocks vs
            # the per-token slot path on the same workload.
            "fused_vs_slot_tok_per_s": (fused["tok_per_s"]
                                        / slot["tok_per_s"]),
            "fused_host_syncs_per_token": fused["host_syncs_per_token"],
            "fused_effective_k": (slot["host_syncs_per_token"]
                                  / fused["host_syncs_per_token"]),
            "fused_ttft_p50_vs_slot": (fused["ttft_ms_p50"]
                                       / slot["ttft_ms_p50"]),
            "fused_itl_p50_vs_slot": ((fused["itl_ms_p50"]
                                       / slot["itl_ms_p50"])
                                      if fused["itl_ms_p50"]
                                      and slot["itl_ms_p50"] else None),
            # Chunked zero-copy admission wins (DESIGN.md §9), all
            # deterministic counters on the long-prompt mixed workload:
            # no dedicated admission sync, no cache-copy dispatch, no
            # decode stall while long prompts stream in.
            "chunked_vs_fused_tok_per_s": (chunked["tok_per_s"]
                                           / fused["tok_per_s"]),
            "chunked_host_syncs_per_token": (
                chunked["host_syncs_per_token"]),
            "chunked_syncs_vs_fused": (chunked["host_syncs_per_token"]
                                       / fused["host_syncs_per_token"]),
            "chunked_cache_copy_dispatches": (
                chunked["cache_copy_dispatches"]),
            "admission_stall_steps_fused": fused["admission_stall_steps"],
            "admission_stall_steps_chunked": (
                chunked["admission_stall_steps"]),
            "chunked_ttft_p50_vs_fused": (chunked["ttft_ms_p50"]
                                          / fused["ttft_ms_p50"]),
            # Paged residency wins (DESIGN.md §10): identical dispatch
            # discipline to chunked (same deterministic counters) but
            # peak KV residency is live pages, not the dense batch
            # cache, and swap/admission copy traffic is zero.
            "paged_vs_chunked_tok_per_s": (paged["tok_per_s"]
                                           / chunked["tok_per_s"]),
            "paged_host_syncs_per_token": paged["host_syncs_per_token"],
            "paged_kv_resident_vs_dense": (
                paged["kv_resident_bytes_peak"]
                / paged["dense_cache_bytes"]),
            "paged_kv_copy_bytes": paged["kv_copy_bytes"],
            "chunked_kv_copy_bytes": chunked["kv_copy_bytes"],
            "fused_kv_copy_bytes": fused["kv_copy_bytes"],
        },
    }
    for r in (wave, slot, fused, chunked, paged, pre_on, pre_off):
        r.pop("_token_seqs", None)      # identity already asserted
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    sp = out["speedup"]
    print(f"\nslot/wave throughput: {sp['throughput_tok_per_s']:.2f}x"
          f"  short-request latency: {sp['short_req_latency']:.2f}x"
          f"  ttft vs whole-response: {sp['ttft_vs_whole_response']:.2f}x")
    print(f"fused/slot throughput: {sp['fused_vs_slot_tok_per_s']:.2f}x"
          f"  syncs/tok: {sp['fused_host_syncs_per_token']:.2f}"
          f"  effective K: {sp['fused_effective_k']:.1f}"
          f"  ttft ratio: {sp['fused_ttft_p50_vs_slot']:.2f}")
    print(f"chunked/fused throughput: "
          f"{sp['chunked_vs_fused_tok_per_s']:.2f}x"
          f"  syncs/tok vs fused: {sp['chunked_syncs_vs_fused']:.2f}"
          f"  cache copies: {sp['chunked_cache_copy_dispatches']}"
          f"  stall steps: {sp['admission_stall_steps_fused']}"
          f" -> {sp['admission_stall_steps_chunked']}")
    print(f"paged/chunked throughput: "
          f"{sp['paged_vs_chunked_tok_per_s']:.2f}x"
          f"  kv resident vs dense: "
          f"{sp['paged_kv_resident_vs_dense']:.2f}x"
          f"  kv copied: {sp['fused_kv_copy_bytes'] // 1024}KiB (fused)"
          f" -> {sp['paged_kv_copy_bytes']}B (paged)")
    po = prefix_out
    print(f"prefix sharing ({n_sessions} sessions): "
          f"prefill chunks {po['off']['prefill_chunks']}"
          f" -> {po['on']['prefill_chunks']}"
          f" ({po['prefill_chunks_ratio']:.1f}x)"
          f"  hits {po['prefix_hits']}"
          f"  tokens saved {po['prefill_tokens_saved']}"
          f"  shared pages peak {po['shared_pages_peak']}"
          f"  kv peak {po['off']['kv_resident_bytes_peak'] // 1024}KiB"
          f" -> {po['on']['kv_resident_bytes_peak'] // 1024}KiB"
          f"  cow {po['cow_copy_bytes'] // 1024}KiB"
          f"  -> {args.out}")
    return out


if __name__ == "__main__":
    main()
