"""Benchmark harness — one section per paper table/figure.

  bench_lockfree   -> Table 2 (multicore penalty), Figures 7/8 (speedups)
  qpn_model        -> Figure 6 (QPN memory-bus model), §5 theoretical max
  bench_pipeline   -> device-level lock vs lock-free (collective bytes)
  bench_kernels    -> Pallas kernel tiles (VMEM fit, intensity, allclose)
  roofline         -> §Roofline table over the dry-run artifacts

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import sys
import traceback

SECTIONS = ["lockfree", "qpn", "pipeline", "kernels", "roofline"]


def main() -> None:
    want = sys.argv[1:] or SECTIONS
    failures = []
    for name in want:
        print(f"\n{'=' * 72}\n# benchmark section: {name}\n{'=' * 72}")
        try:
            if name == "lockfree":
                from benchmarks import bench_lockfree
                bench_lockfree.main([])
            elif name == "qpn":
                from benchmarks import qpn_model
                qpn_model.main()
            elif name == "pipeline":
                from benchmarks import bench_pipeline
                bench_pipeline.main()
            elif name == "kernels":
                from benchmarks import bench_kernels
                bench_kernels.main()
            elif name == "roofline":
                from benchmarks import roofline
                roofline.main()
            else:
                raise KeyError(f"unknown section {name}; have {SECTIONS}")
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    print(f"\n{'=' * 72}\n# benchmarks done; failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
